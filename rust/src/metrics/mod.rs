//! Inference cost metrics: FLOPs, BOPs (eq. 1), weight memory, cost C (eq. 2).
//!
//! BOPs for one layer with b_w-bit weights, b_a-bit activations, n input
//! channels, m output channels, k×k filters (per output position):
//!
//! ```text
//! BOPs ≈ m·n·k²·(b_a·b_w + b_a + b_w + log2(n·k²))            (eq. 1)
//! ```
//!
//! (dense layers use k = 1).  The summary inference cost compares against
//! the CNV-W1A1 reference:
//!
//! ```text
//! C = ½ (BOPs/BOPs_CNV + WM/WM_CNV)                            (eq. 2)
//! ```

use crate::ir::{Graph, Node};

/// FLOPs for one inference (2·MACs, the keras-Opcounter convention).
pub fn flops(g: &Graph) -> u64 {
    2 * g.total_macs()
}

/// BOPs for a single layer (eq. 1).  `spatial` multiplies by the number of
/// output positions for convolutions (BOPs count all MACs in the layer).
pub fn layer_bops(
    m_out: u64,
    n_in: u64,
    k: u64,
    ba: u64,
    bw: u64,
    spatial: u64,
) -> f64 {
    let nk2 = (n_in * k * k) as f64;
    spatial as f64
        * (m_out * n_in * k * k) as f64
        * ((ba * bw + ba + bw) as f64 + nk2.log2())
}

/// Total BOPs for a graph; activation precision comes from each compute
/// node's `in_bits` (set by datatype inference; falls back to input bits).
pub fn bops(g: &Graph) -> f64 {
    let mut cur_bits = g.input_bits as u64;
    let mut total = 0.0;
    for node in &g.nodes {
        match node {
            Node::Conv2D { out_hw, in_ch, out_ch, kernel, weight_bits, in_bits, .. } => {
                let ba = if *in_bits > 0 { *in_bits as u64 } else { cur_bits };
                total += layer_bops(
                    *out_ch as u64,
                    *in_ch as u64,
                    *kernel as u64,
                    ba,
                    *weight_bits as u64,
                    (*out_hw * *out_hw) as u64,
                );
            }
            Node::Dense { in_features, out_features, weight_bits, in_bits, .. } => {
                let ba = if *in_bits > 0 { *in_bits as u64 } else { cur_bits };
                total += layer_bops(
                    *out_features as u64,
                    *in_features as u64,
                    1,
                    ba,
                    *weight_bits as u64,
                    1,
                );
            }
            Node::ReLU { act_bits, .. } => cur_bits = *act_bits as u64,
            Node::BipolarAct { .. } => cur_bits = 1,
            Node::MultiThreshold { levels, .. } => {
                cur_bits = (32 - levels.leading_zeros()).max(1) as u64
            }
            _ => {}
        }
    }
    total
}

/// Weight memory: total bits needed to store all weights (WM).
pub fn weight_memory_bits(g: &Graph) -> u64 {
    g.nodes
        .iter()
        .filter(|n| n.is_compute())
        .map(|n| {
            let bits = match n {
                Node::Conv2D { weight_bits, .. } | Node::Dense { weight_bits, .. } => *weight_bits,
                _ => 0,
            };
            n.params() * bits as u64
        })
        .sum()
}

/// Reference costs of the full-size CNV-W1A1 (the eq. 2 denominators).
#[derive(Clone, Copy, Debug)]
pub struct CostReference {
    pub bops: f64,
    pub wm_bits: f64,
}

/// Inference cost C (eq. 2) relative to a reference design.
pub fn inference_cost(g: &Graph, reference: &CostReference) -> f64 {
    0.5 * (bops(g) / reference.bops + weight_memory_bits(g) as f64 / reference.wm_bits)
}

pub fn cost_reference_from(g: &Graph) -> CostReference {
    CostReference { bops: bops(g), wm_bits: weight_memory_bits(g) as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_graph(wbits: u32, abits_relu: u32) -> Graph {
        let json = format!(
            r#"{{
            "name":"d","task":"kws","flow":"finn","input_shape":[64],
            "input_bits":{abits_relu},"nodes":[
              {{"op":"Dense","name":"fc1","in_features":64,"out_features":32,
               "weight_bits":{wbits},"params":2048}}
            ],"total_params":2048}}"#
        );
        Graph::from_json_str(&json).unwrap()
    }

    #[test]
    fn eq1_dense_formula() {
        let g = dense_graph(3, 3);
        // m·n·(ba·bw + ba + bw + log2(n)) = 32·64·(9+3+3+6) = 43008
        let want = 32.0 * 64.0 * (9.0 + 3.0 + 3.0 + 64f64.log2());
        assert!((bops(&g) - want).abs() < 1e-6, "{}", bops(&g));
    }

    #[test]
    fn bops_scale_with_precision() {
        assert!(bops(&dense_graph(8, 8)) > bops(&dense_graph(1, 8)));
        assert!(bops(&dense_graph(3, 8)) > bops(&dense_graph(3, 1)));
    }

    #[test]
    fn weight_memory_counts_bits() {
        assert_eq!(weight_memory_bits(&dense_graph(3, 8)), 2048 * 3);
        assert_eq!(weight_memory_bits(&dense_graph(1, 8)), 2048);
    }

    #[test]
    fn cost_of_reference_is_one() {
        let g = dense_graph(1, 1);
        let r = cost_reference_from(&g);
        assert!((inference_cost(&g, &r) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn flops_are_2x_macs() {
        let g = dense_graph(3, 3);
        assert_eq!(flops(&g), 2 * 64 * 32);
    }
}
