//! # tinyml-codesign
//!
//! Reproduction of *"Open-source FPGA-ML codesign for the MLPerf Tiny
//! Benchmark"* (Borras et al., MLSys 2022) as a three-layer Rust + JAX +
//! Pallas stack.  Python authors and AOT-compiles the quantized models
//! (`python/compile/`, build time only); this crate owns everything else:
//!
//! * [`ir`] / [`passes`] — the QONNX-like graph IR and the paper's compiler
//!   optimizations (BN folding, streamlining, ReLU merging, accumulator
//!   minimization, softmax→TopK).
//! * [`dataflow`] / [`fifo`] — the spatial dataflow architecture simulator
//!   and the FIFO-depth optimization of §3.1.2/§3.5.
//! * [`board`] / [`resources`] / [`power`] — Pynq-Z2 and Arty A7-100T
//!   models: LUT/FF/BRAM/DSP estimation and the energy-per-inference model.
//! * [`metrics`] — FLOPs, BOPs (eq. 1), weight memory, inference cost (eq. 2).
//! * [`dse`] / [`surrogate`] — Bayesian optimization + adaptive ASHA for the
//!   Fig. 2/3/4 design-space explorations.
//! * [`runtime`] — the PJRT bridge: loads `artifacts/*.hlo.txt`, executes
//!   inference and SGD train steps (Python never on the request path).
//! * [`coordinator`] — the end-to-end codesign flow driver and the async
//!   batching inference engine.
//! * [`eembc`] — a simulation of the EEMBC EnergyRunner™ + test harness
//!   (performance, energy, and accuracy modes over a paced serial link).
//! * [`data`] — deterministic synthetic datasets shared bit-exactly with
//!   the Python training side (splitmix64 templates).

pub mod board;
pub mod coordinator;
pub mod data;
pub mod dataflow;
pub mod dse;
pub mod eembc;
pub mod fifo;
pub mod ir;
pub mod metrics;
pub mod passes;
pub mod power;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod surrogate;

/// Canonical location of the AOT artifacts relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory from the current working directory or
/// the `TINYML_ARTIFACTS` environment variable.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("TINYML_ARTIFACTS") {
        return p.into();
    }
    for base in [".", "..", "../.."] {
        let p = std::path::Path::new(base).join(ARTIFACTS_DIR);
        if p.join("index.json").exists() {
            return p;
        }
    }
    std::path::PathBuf::from(ARTIFACTS_DIR)
}
