//! # tinyml-codesign
//!
//! Reproduction of *"Open-source FPGA-ML codesign for the MLPerf Tiny
//! Benchmark"* (Borras et al., MLSys 2022) as a three-layer Rust + JAX +
//! Pallas stack.  Python authors and AOT-compiles the quantized models
//! (`python/compile/`, build time only); this crate owns everything else:
//!
//! * [`ir`] / [`passes`] — the QONNX-like graph IR and the paper's compiler
//!   optimizations (BN folding, streamlining, ReLU merging, accumulator
//!   minimization, softmax→TopK).
//! * [`dataflow`] / [`fifo`] — the spatial dataflow architecture simulator
//!   and the FIFO-depth optimization of §3.1.2/§3.5.
//! * [`board`] / [`resources`] / [`power`] — Pynq-Z2 and Arty A7-100T
//!   models: LUT/FF/BRAM/DSP estimation and the energy-per-inference model.
//! * [`metrics`] — FLOPs, BOPs (eq. 1), weight memory, inference cost (eq. 2).
//! * [`dse`] / [`surrogate`] — Bayesian optimization + adaptive ASHA for the
//!   Fig. 2/3/4 design-space explorations.
//! * [`runtime`] — the execution backend behind a stable `Runtime` /
//!   `LoadedModel` facade: with `--features pjrt` the PJRT bridge loads
//!   `artifacts/*.hlo.txt` and executes inference and SGD train steps
//!   (Python never on the request path); the default build substitutes a
//!   deterministic surrogate backend so the stack runs anywhere.
//! * [`coordinator`] — the end-to-end codesign flow driver and the async
//!   batching inference engine.
//! * [`fleet`] — the multi-board serving plane: a [`fleet::registry`] of
//!   heterogeneous board instances (board model × task × folding schedule,
//!   each carrying its dataflow-simulated latency and power model), a
//!   [`fleet::router`] with pluggable policies (round-robin, least-loaded,
//!   energy-aware, latency-SLO) plus admission control and bounded-queue
//!   backpressure, per-board worker threads that reuse the dynamic batcher
//!   with work stealing between same-task replicas and execute through the
//!   engine's `BatchExecutor` trait (the simulated dataflow hold lives in
//!   the executor, so sim and PJRT boards share one worker loop),
//!   a multi-tenant class-aware queue plane ([`fleet::queue`]: every
//!   request carries a (tenant, priority) tag, strict-priority pickup for
//!   interactive traffic with an anti-starvation guard, weighted
//!   deficit-round-robin between standard and batch, and tiered admission
//!   that sheds batch first under overload),
//!   [`fleet::autoscale`] growing/shrinking same-task replicas at runtime
//!   from telemetry (urgent queue depth, predicted latency vs SLO,
//!   utilization) with drain-then-join retirement, and
//!   [`fleet::telemetry`] aggregating fleet-level p50/p99 latency,
//!   throughput, energy per inference, per-class/per-tenant splits,
//!   board-seconds, and the scale history into [`report::json`].
//! * [`kernels`] — the packed quantized kernel core behind every surrogate
//!   forward: templates/projections packed once into contiguous i8 with
//!   per-row scales ([`kernels::PackedLinear`], mirroring the paper's
//!   4–8-bit MVAU weight memories), batched i32-accumulating GEMM that
//!   walks the weight matrix once per batch, an O(n) prefix-sum smoothing
//!   pass ([`kernels::SmoothKernel`]), and a caller-owned
//!   [`kernels::ScratchArena`] so the steady-state serve loop performs
//!   zero heap allocations inside the kernels.
//! * [`eembc`] — a simulation of the EEMBC EnergyRunner™ + test harness
//!   (performance, energy, and accuracy modes over a paced serial link).
//! * [`data`] — deterministic synthetic datasets shared bit-exactly with
//!   the Python training side (splitmix64 templates).
//! * [`error`] — std-only anyhow-subset error type (the offline build
//!   image has no external crates).

pub mod board;
pub mod coordinator;
pub mod data;
pub mod dataflow;
pub mod dse;
pub mod eembc;
pub mod error;
pub mod fifo;
pub mod fleet;
pub mod ir;
pub mod kernels;
pub mod metrics;
pub mod passes;
pub mod power;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod surrogate;

/// Canonical location of the AOT artifacts relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory from the current working directory or
/// the `TINYML_ARTIFACTS` environment variable.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("TINYML_ARTIFACTS") {
        return p.into();
    }
    for base in [".", "..", "../.."] {
        let p = std::path::Path::new(base).join(ARTIFACTS_DIR);
        if p.join("index.json").exists() {
            return p;
        }
    }
    std::path::PathBuf::from(ARTIFACTS_DIR)
}
