//! EEMBC EnergyRunner™ + test harness simulation (§4.3-4.4).
//!
//! The physical rig — host PC, DUT over USB-serial, IO manager (Arduino
//! UNO) as a serial bridge, level shifters, Joulescope JS110 energy
//! monitor, GPIO timing pin — is modeled with a *virtual-time* harness:
//!
//! * [`SerialLink`] paces every byte at the configured baud rate
//!   (115 200 in performance mode; 9 600 in energy mode, the IO-manager
//!   limit — §4.4.2) and accumulates virtual seconds.
//! * [`Dut`] implements the test-harness command protocol (`name%`,
//!   `db load`, `infer`, `results%`) over the link; inference latency
//!   comes from the dataflow simulation (the accelerator), while sample
//!   outputs come from real PJRT inference — both layers are exercised.
//! * [`EnergyMonitor`] integrates the power model over the GPIO-framed
//!   window (the DUT holds the pin low ≥ 10 µs to frame a measurement).
//!
//! Methodology follows §4.4.1/§4.4.2: 5 samples; for each, enough batch-1
//! inferences to accumulate ≥ 10 s of continuous accelerator runtime;
//! median over the 5 samples.  Accuracy mode streams the whole test set
//! one sample at a time.

use crate::data::{self, Sample};
use crate::runtime::{LoadedModel, Runtime};
use crate::error::Result;

/// Byte-paced serial connection with a virtual clock.
#[derive(Clone, Debug)]
pub struct SerialLink {
    pub baud: u64,
    pub virtual_time_s: f64,
    pub bytes_moved: u64,
}

impl SerialLink {
    pub fn new(baud: u64) -> Self {
        Self { baud, virtual_time_s: 0.0, bytes_moved: 0 }
    }

    /// Move `n` bytes across the link (10 bits per byte: start + 8 + stop).
    pub fn transfer(&mut self, n: u64) {
        self.bytes_moved += n;
        self.virtual_time_s += (n * 10) as f64 / self.baud as f64;
    }
}

/// Performance characteristics of the deployed design (from the codesign
/// flow: dataflow simulation + power model).
#[derive(Clone, Copy, Debug)]
pub struct DesignPerf {
    pub latency_s: f64,
    pub power_w: f64,
}

/// The device under test: harness + accelerator + (simulated) platform.
pub struct Dut<'m> {
    pub model: &'m mut LoadedModel,
    pub perf: DesignPerf,
    pub loaded: Option<Vec<f32>>,
    /// Virtual timestamp counter (the DUT-internal timer of §4.4.1).
    pub timer_s: f64,
    pub gpio_low: bool,
}

/// What the DUT reports back for one `infer` command.
#[derive(Clone, Debug)]
pub struct InferReply {
    pub iterations: u64,
    pub window_s: f64,
    pub output: Vec<f32>,
}

impl<'m> Dut<'m> {
    pub fn new(model: &'m mut LoadedModel, perf: DesignPerf) -> Self {
        Self { model, perf, loaded: None, timer_s: 0.0, gpio_low: false }
    }

    pub fn name(&self) -> String {
        format!("tinyml-codesign/{}", self.model.manifest.name)
    }

    /// `db load`: receive one sample into DUT memory.
    pub fn load_sample(&mut self, link: &mut SerialLink, x: &[f32]) {
        // EEMBC sends samples as hex text: 2 chars per byte + framing.
        link.transfer((x.len() * 4 * 2 + 16) as u64);
        self.loaded = Some(x.to_vec());
    }

    /// `infer <n>`: run n batch-1 inferences back-to-back.  One inference
    /// runs for real through PJRT (producing the output the accuracy mode
    /// needs); the accelerator-time accounting uses the simulated design
    /// latency for all n (§4.4.1 measures the accelerator, not the CPU
    /// stand-in).
    pub fn infer(&mut self, rt: &Runtime, n: u64) -> Result<InferReply> {
        let x = self.loaded.clone().expect("no sample loaded");
        self.gpio_low = true; // frame the timing window (energy mode)
        let output = self.model.infer1(rt, &x)?;
        let window = self.perf.latency_s * n as f64;
        self.timer_s += window;
        self.gpio_low = false;
        Ok(InferReply { iterations: n, window_s: window, output })
    }
}

/// Joulescope JS110 stand-in: integrates power over GPIO-framed windows.
pub struct EnergyMonitor {
    /// Sampling noise (fraction of reading, deterministic per window).
    pub noise_frac: f64,
    seed: u64,
}

impl EnergyMonitor {
    pub fn new(seed: u64) -> Self {
        Self { noise_frac: 0.015, seed }
    }

    /// Energy over a window framed by the GPIO pin (must be ≥ 10 µs).
    pub fn measure_uj(&mut self, power_w: f64, window_s: f64) -> f64 {
        assert!(window_s >= 10e-6, "GPIO frame must be >= 10 us");
        let mut rng = crate::data::prng::SplitMix64::new(self.seed);
        self.seed = rng.next_u64();
        let noise = 1.0 + self.noise_frac * (rng.next_f64() - 0.5) * 2.0;
        power_w * window_s * 1e6 * noise
    }
}

/// Benchmark-mode results (what the runner prints / the paper tabulates).
#[derive(Clone, Debug)]
pub struct PerformanceResult {
    pub median_latency_s: f64,
    pub throughput_inf_per_s: f64,
    pub serial_time_s: f64,
    pub total_inferences: u64,
}

#[derive(Clone, Debug)]
pub struct EnergyResult {
    pub median_energy_uj: f64,
    pub mean_power_w: f64,
}

#[derive(Clone, Debug)]
pub struct AccuracyResult {
    pub metric: String, // "top1" | "auc"
    pub value: f64,
    pub n_samples: usize,
}

/// The host-side EnergyRunner application.
pub struct Runner {
    pub perf_baud: u64,
    pub energy_baud: u64,
    /// Minimum continuous accelerator runtime per sample (§4.4.1: 10 s).
    pub min_window_s: f64,
    pub n_samples: usize,
}

impl Default for Runner {
    fn default() -> Self {
        Self { perf_baud: 115_200, energy_baud: 9_600, min_window_s: 10.0, n_samples: 5 }
    }
}

fn median(values: &mut [f64]) -> f64 {
    values.sort_by(|a, b| a.total_cmp(b));
    values[values.len() / 2]
}

impl Runner {
    /// Performance mode (§4.4.1): median batch-1 latency over 5 samples.
    pub fn performance_mode(
        &self,
        rt: &Runtime,
        dut: &mut Dut,
        samples: &[Sample],
    ) -> Result<PerformanceResult> {
        let mut link = SerialLink::new(self.perf_baud);
        link.transfer(dut.name().len() as u64 + 8); // name% handshake
        let mut latencies = Vec::new();
        let mut total_inf = 0u64;
        for s in samples.iter().take(self.n_samples) {
            dut.load_sample(&mut link, &s.x);
            let iters = (self.min_window_s / dut.perf.latency_s).ceil().max(1.0) as u64;
            let reply = dut.infer(rt, iters)?;
            total_inf += reply.iterations;
            latencies.push(reply.window_s / reply.iterations as f64);
            link.transfer(64); // results% reply
        }
        let med = median(&mut latencies);
        Ok(PerformanceResult {
            median_latency_s: med,
            throughput_inf_per_s: 1.0 / med,
            serial_time_s: link.virtual_time_s,
            total_inferences: total_inf,
        })
    }

    /// Energy mode (§4.4.2): IO-manager bridge at 9 600 baud, GPIO-framed
    /// windows integrated by the energy monitor, median over samples.
    pub fn energy_mode(
        &self,
        rt: &Runtime,
        dut: &mut Dut,
        samples: &[Sample],
    ) -> Result<EnergyResult> {
        let mut link = SerialLink::new(self.energy_baud);
        let mut monitor = EnergyMonitor::new(0xE4E6);
        link.transfer(dut.name().len() as u64 + 8);
        let mut energies = Vec::new();
        for s in samples.iter().take(self.n_samples) {
            dut.load_sample(&mut link, &s.x);
            let iters = (self.min_window_s / dut.perf.latency_s).ceil().max(1.0) as u64;
            let reply = dut.infer(rt, iters)?;
            let e_window = monitor.measure_uj(dut.perf.power_w, reply.window_s.max(10e-6));
            energies.push(e_window / reply.iterations as f64);
            link.transfer(64);
        }
        Ok(EnergyResult {
            median_energy_uj: median(&mut energies),
            mean_power_w: dut.perf.power_w,
        })
    }

    /// Accuracy mode: the whole test set, one sample at a time (§4.4.1).
    pub fn accuracy_mode(
        &self,
        rt: &Runtime,
        dut: &mut Dut,
        test_set: &[Sample],
    ) -> Result<AccuracyResult> {
        let mut link = SerialLink::new(self.perf_baud);
        let task = dut.model.manifest.task.clone();
        if task == "ad" {
            let mut scores = Vec::with_capacity(test_set.len());
            for s in test_set {
                dut.load_sample(&mut link, &s.x);
                let score = dut.model.anomaly_score1(rt, &s.x)?;
                scores.push((score, s.label == 1));
            }
            Ok(AccuracyResult {
                metric: "auc".into(),
                value: data::roc_auc(&scores),
                n_samples: test_set.len(),
            })
        } else {
            let mut correct = 0usize;
            for s in test_set {
                dut.load_sample(&mut link, &s.x);
                let pred = dut.model.classify1(rt, &s.x)?;
                if pred == s.label as usize {
                    correct += 1;
                }
            }
            Ok(AccuracyResult {
                metric: "top1".into(),
                value: correct as f64 / test_set.len() as f64,
                n_samples: test_set.len(),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_pacing_115200_vs_9600() {
        let mut fast = SerialLink::new(115_200);
        let mut slow = SerialLink::new(9_600);
        fast.transfer(1000);
        slow.transfer(1000);
        assert!((fast.virtual_time_s - 1000.0 * 10.0 / 115_200.0).abs() < 1e-12);
        assert!(slow.virtual_time_s / fast.virtual_time_s > 11.0);
    }

    #[test]
    fn energy_monitor_integrates_power() {
        let mut m = EnergyMonitor::new(7);
        let e = m.measure_uj(1.6, 10.0);
        // 1.6 W * 10 s = 16 J = 16e6 uJ, ±1.5% noise.
        assert!((e - 16e6).abs() < 0.03 * 16e6, "{e}");
    }

    #[test]
    #[should_panic]
    fn energy_monitor_rejects_short_window() {
        let mut m = EnergyMonitor::new(7);
        m.measure_uj(1.0, 1e-6);
    }

    #[test]
    fn median_of_odd() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn iteration_count_reaches_min_window() {
        // 20 us latency -> 10 s window needs 500 000 iterations.
        let iters = (10.0f64 / 20e-6).ceil() as u64;
        assert_eq!(iters, 500_000);
    }
}
