//! Search spaces for the paper's three explorations.
//!
//! * [`IcNasSpace`] — the restricted ResNet-style NAS of §3.1.1 (Fig. 2):
//!   stacks fixed per scan; per-layer filters {2,4,8,16,32}, kernel sizes
//!   {1,2,3}, strides, average-pool and skip-connection toggles.
//! * [`CnvSpace`] — the ASHA scan of §3.2.1 (Fig. 3): conv filters 32-512,
//!   pooling toggles, strides/kernels 1-4, FC width 16-512, weight and
//!   activation bit widths {1,2}.
//!
//! Points decode from normalized [0,1]^d vectors (for the GP) or from a
//! seeded stream (for ASHA random sampling); each decodes to FLOPs/BOPs/
//! WM metrics the paper plots on its x-axes.

use crate::data::prng::SplitMix64;

/// A decoded IC NAS configuration.
#[derive(Clone, Debug)]
pub struct IcNasConfig {
    pub stacks: usize,
    pub filters: Vec<usize>,
    pub kernels: Vec<usize>,
    pub strides: Vec<usize>,
    pub avg_pool: bool,
    pub skip: bool,
}

pub struct IcNasSpace {
    pub stacks: usize,
}

const FILTER_CHOICES: [usize; 4] = [2, 4, 8, 16];
const KERNEL_CHOICES: [usize; 3] = [1, 2, 3];
const STRIDE_CHOICES: [usize; 3] = [1, 2, 4];

impl IcNasSpace {
    /// 3 conv layers per stack (the reference ResNet stack shape).
    pub fn dim(&self) -> usize {
        self.stacks * 3 * 3 + 2 // (filters, kernel, stride) per layer + 2 toggles
    }

    pub fn decode(&self, x: &[f64]) -> IcNasConfig {
        assert_eq!(x.len(), self.dim());
        let n_layers = self.stacks * 3;
        let pick = |v: f64, n: usize| ((v * n as f64) as usize).min(n - 1);
        let mut filters = Vec::new();
        let mut kernels = Vec::new();
        let mut strides = Vec::new();
        for l in 0..n_layers {
            filters.push(FILTER_CHOICES[pick(x[3 * l], FILTER_CHOICES.len())]);
            kernels.push(KERNEL_CHOICES[pick(x[3 * l + 1], KERNEL_CHOICES.len())]);
            strides.push(STRIDE_CHOICES[pick(x[3 * l + 2], STRIDE_CHOICES.len())]);
        }
        IcNasConfig {
            stacks: self.stacks,
            filters,
            kernels,
            strides,
            avg_pool: x[3 * n_layers] > 0.5,
            skip: x[3 * n_layers + 1] > 0.5,
        }
    }

    pub fn sample(&self, rng: &mut SplitMix64) -> (Vec<f64>, IcNasConfig) {
        let x: Vec<f64> = (0..self.dim()).map(|_| rng.next_f64()).collect();
        let c = self.decode(&x);
        (x, c)
    }
}

impl IcNasConfig {
    /// MFLOPs of the decoded model on 32x32x3 inputs (2*MACs, §3.1.1).
    pub fn mflops(&self) -> f64 {
        let mut hw = 32usize;
        let mut in_ch = 3usize;
        let mut macs = 0u64;
        for ((&f, &k), &s) in self.filters.iter().zip(&self.kernels).zip(&self.strides) {
            let out_hw = hw.div_ceil(s);
            macs += (out_hw * out_hw * k * k * in_ch * f) as u64;
            hw = out_hw;
            in_ch = f;
        }
        // Final FC over (avg-pooled or flattened) features to 10 classes.
        let feats = if self.avg_pool { in_ch } else { hw * hw * in_ch };
        macs += (feats * 10) as u64;
        2.0 * macs as f64 / 1e6
    }

    pub fn max_filters(&self) -> usize {
        self.filters.iter().copied().max().unwrap_or(0)
    }

    /// Deterministic identity for surrogate noise.
    pub fn seed(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x100000001b3);
        };
        for &f in &self.filters {
            mix(f as u64);
        }
        for &k in &self.kernels {
            mix(k as u64 + 100);
        }
        for &s in &self.strides {
            mix(s as u64 + 200);
        }
        mix(self.avg_pool as u64 + 300);
        mix(self.skip as u64 + 400);
        h
    }
}

/// A decoded CNV-variant configuration (Fig. 3 / §3.2.1).
#[derive(Clone, Debug)]
pub struct CnvConfig {
    /// Channels of the three conv blocks (two convs each).
    pub block_ch: [usize; 3],
    pub fc_dim: usize,
    pub weight_bits: u32,
    pub act_bits: u32,
    pub kernel: usize,
}

pub struct CnvSpace;

impl CnvSpace {
    pub fn sample(&self, rng: &mut SplitMix64) -> CnvConfig {
        let ch = |rng: &mut SplitMix64| 32usize << rng.next_below(5); // 32..512
        CnvConfig {
            block_ch: [ch(rng), ch(rng), ch(rng)],
            fc_dim: 16usize << rng.next_below(6), // 16..512
            weight_bits: 1 + rng.next_below(2) as u32,
            act_bits: 1 + rng.next_below(2) as u32,
            kernel: 2 + rng.next_below(3) as usize, // 2..4
        }
    }

    /// The reference CNV-W1A1.
    pub fn reference(&self) -> CnvConfig {
        CnvConfig {
            block_ch: [64, 128, 256],
            fc_dim: 512,
            weight_bits: 1,
            act_bits: 1,
            kernel: 3,
        }
    }
}

impl CnvConfig {
    /// (BOPs, weight-memory bits) via eq. 1 over the CNV topology shape.
    pub fn costs(&self) -> (f64, f64) {
        let mut hw = 32usize;
        let mut in_ch = 3usize;
        let mut bops = 0.0f64;
        let mut wm = 0.0f64;
        let mut in_bits = 8u64; // 8-bit input layer
        for (b, &ch) in self.block_ch.iter().enumerate() {
            for _ in 0..2 {
                let out_hw = hw.saturating_sub(self.kernel - 1).max(1);
                let nk2 = (in_ch * self.kernel * self.kernel) as f64;
                let macs = (out_hw * out_hw) as f64 * nk2 * ch as f64;
                bops += macs
                    * ((in_bits * self.weight_bits as u64) as f64
                        + (in_bits + self.weight_bits as u64) as f64
                        + nk2.log2());
                wm += nk2 * ch as f64 * self.weight_bits as f64;
                hw = out_hw;
                in_ch = ch;
                in_bits = self.act_bits as u64;
            }
            if b < 2 {
                hw /= 2;
            }
        }
        let dims = [in_ch * hw * hw, self.fc_dim, self.fc_dim, 10];
        for w in dims.windows(2) {
            let macs = (w[0] * w[1]) as f64;
            bops += macs
                * ((in_bits * self.weight_bits as u64) as f64
                    + (in_bits + self.weight_bits as u64) as f64
                    + (w[0] as f64).log2());
            wm += macs * self.weight_bits as f64;
        }
        (bops, wm)
    }

    /// Inference cost C (eq. 2) vs the reference CNV-W1A1.
    pub fn inference_cost(&self, reference: &CnvConfig) -> f64 {
        let (b, w) = self.costs();
        let (rb, rw) = reference.costs();
        0.5 * (b / rb + w / rw)
    }

    pub fn seed(&self) -> u64 {
        (self.block_ch[0] as u64)
            .wrapping_mul(31)
            .wrapping_add(self.block_ch[1] as u64)
            .wrapping_mul(31)
            .wrapping_add(self.block_ch[2] as u64)
            .wrapping_mul(31)
            .wrapping_add(self.fc_dim as u64)
            .wrapping_mul(31)
            .wrapping_add((self.weight_bits * 10 + self.act_bits) as u64)
            .wrapping_mul(31)
            .wrapping_add(self.kernel as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_in_bounds() {
        let space = IcNasSpace { stacks: 2 };
        let mut rng = SplitMix64::new(1);
        for _ in 0..50 {
            let (_, c) = space.sample(&mut rng);
            assert_eq!(c.filters.len(), 6);
            assert!(c.filters.iter().all(|f| FILTER_CHOICES.contains(f)));
            assert!(c.mflops() > 0.0);
        }
    }

    #[test]
    fn more_filters_more_flops() {
        let space = IcNasSpace { stacks: 1 };
        let lo = space.decode(&vec![0.0; space.dim()]);
        let hi = space.decode(&vec![0.99; space.dim()]);
        // hi has 16 filters everywhere but also stride 4; compare directly.
        let mut hi_f = hi.clone();
        hi_f.strides = lo.strides.clone();
        assert!(hi_f.mflops() > lo.mflops());
    }

    #[test]
    fn cnv_reference_cost_is_one() {
        let space = CnvSpace;
        let r = space.reference();
        assert!((r.inference_cost(&space.reference()) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cnv_smaller_is_cheaper() {
        let space = CnvSpace;
        let small = CnvConfig {
            block_ch: [32, 32, 32],
            fc_dim: 16,
            weight_bits: 1,
            act_bits: 1,
            kernel: 3,
        };
        assert!(small.inference_cost(&space.reference()) < 0.3);
        let big = CnvConfig {
            block_ch: [128, 256, 512],
            fc_dim: 512,
            weight_bits: 2,
            act_bits: 2,
            kernel: 3,
        };
        assert!(big.inference_cost(&space.reference()) > 1.5);
    }

    #[test]
    fn cnv_w2_costs_more_than_w1() {
        let space = CnvSpace;
        let mut w2 = space.reference();
        w2.weight_bits = 2;
        assert!(w2.inference_cost(&space.reference()) > 1.0);
    }
}
