//! Gaussian-process Bayesian optimization (KerasTuner-style, §3.1.1).
//!
//! Small, dependency-free GP: RBF kernel, Cholesky solve, expected
//! improvement maximized over a random candidate pool.  Dimensions are
//! normalized to [0,1]^d by the caller.

use crate::data::prng::SplitMix64;

/// Dense lower-triangular Cholesky; returns None if not PD.
pub fn cholesky(a: &[Vec<f64>]) -> Option<Vec<Vec<f64>>> {
    let n = a.len();
    let mut l = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i][j];
            for k in 0..j {
                sum -= l[i][k] * l[j][k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i][j] = sum.sqrt();
            } else {
                l[i][j] = sum / l[j][j];
            }
        }
    }
    Some(l)
}

/// Solve L y = b (forward), then L^T x = y (backward).
pub fn chol_solve(l: &[Vec<f64>], b: &[f64]) -> Vec<f64> {
    let n = l.len();
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i][k] * y[k];
        }
        y[i] = s / l[i][i];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[k][i] * x[k];
        }
        x[i] = s / l[i][i];
    }
    x
}

fn rbf(a: &[f64], b: &[f64], lengthscale: f64) -> f64 {
    let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (-0.5 * d2 / (lengthscale * lengthscale)).exp()
}

/// Standard normal pdf/cdf (Abramowitz-Stegun erf approximation).
fn phi(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

fn cdf(x: f64) -> f64 {
    // erf via A&S 7.1.26.
    let t = 1.0 / (1.0 + 0.3275911 * x.abs() / std::f64::consts::SQRT_2);
    let erf = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x / 2.0).exp();
    if x >= 0.0 { 0.5 * (1.0 + erf) } else { 0.5 * (1.0 - erf) }
}

/// GP posterior + EI-driven suggestion.
pub struct GpOptimizer {
    pub xs: Vec<Vec<f64>>,
    pub ys: Vec<f64>,
    pub lengthscale: f64,
    pub noise: f64,
    pub candidates: usize,
    rng: SplitMix64,
    dim: usize,
}

impl GpOptimizer {
    pub fn new(dim: usize, seed: u64) -> Self {
        Self {
            xs: Vec::new(),
            ys: Vec::new(),
            // Median pairwise distance in [0,1]^d grows ~ sqrt(d/6); scale
            // the RBF lengthscale with sqrt(dim) so the GP stays informative
            // in the 20-dim NAS space.
            lengthscale: 0.3 * (dim as f64).sqrt().max(1.0),
            noise: 1e-3,
            candidates: 256,
            rng: SplitMix64::new(seed),
            dim,
        }
    }

    pub fn observe(&mut self, x: Vec<f64>, y: f64) {
        assert_eq!(x.len(), self.dim);
        self.xs.push(x);
        self.ys.push(y);
    }

    fn posterior(&self, x: &[f64], l: &[Vec<f64>], alpha: &[f64]) -> (f64, f64) {
        let n = self.xs.len();
        let kx: Vec<f64> = (0..n).map(|i| rbf(&self.xs[i], x, self.lengthscale)).collect();
        let mean: f64 = kx.iter().zip(alpha).map(|(a, b)| a * b).sum();
        // var = k(x,x) - kx^T K^-1 kx via forward solve.
        let v = {
            let mut y = vec![0.0; n];
            for i in 0..n {
                let mut s = kx[i];
                for k in 0..i {
                    s -= l[i][k] * y[k];
                }
                y[i] = s / l[i][i];
            }
            y
        };
        let var = (1.0 + self.noise - v.iter().map(|a| a * a).sum::<f64>()).max(1e-9);
        (mean, var.sqrt())
    }

    /// Suggest the next point: random for the first few, then max-EI over
    /// a random candidate pool.
    pub fn suggest(&mut self) -> Vec<f64> {
        if self.xs.len() < 4 {
            return (0..self.dim).map(|_| self.rng.next_f64()).collect();
        }
        let n = self.xs.len();
        // Normalize y to zero mean, unit-ish scale for GP stability.
        let mean_y = self.ys.iter().sum::<f64>() / n as f64;
        let std_y = (self.ys.iter().map(|y| (y - mean_y) * (y - mean_y)).sum::<f64>()
            / n as f64)
            .sqrt()
            .max(1e-6);
        let ys_n: Vec<f64> = self.ys.iter().map(|y| (y - mean_y) / std_y).collect();
        let mut k = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..n {
                k[i][j] = rbf(&self.xs[i], &self.xs[j], self.lengthscale);
            }
            k[i][i] += self.noise;
        }
        let Some(l) = cholesky(&k) else {
            return (0..self.dim).map(|_| self.rng.next_f64()).collect();
        };
        let alpha = chol_solve(&l, &ys_n);
        let best = ys_n.iter().cloned().fold(f64::NEG_INFINITY, f64::max);

        let mut best_x = Vec::new();
        let mut best_ei = f64::NEG_INFINITY;
        for _ in 0..self.candidates {
            let x: Vec<f64> = (0..self.dim).map(|_| self.rng.next_f64()).collect();
            let (mu, sigma) = self.posterior(&x, &l, &alpha);
            let z = (mu - best - 0.01) / sigma;
            let ei = sigma * (z * cdf(z) + phi(z));
            if ei > best_ei {
                best_ei = ei;
                best_x = x;
            }
        }
        best_x
    }

    pub fn best(&self) -> Option<(&Vec<f64>, f64)> {
        self.ys
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, &y)| (&self.xs[i], y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_identity() {
        let a = vec![vec![4.0, 2.0], vec![2.0, 3.0]];
        let l = cholesky(&a).unwrap();
        // L L^T == A
        let a00 = l[0][0] * l[0][0];
        let a10 = l[1][0] * l[0][0];
        let a11 = l[1][0] * l[1][0] + l[1][1] * l[1][1];
        assert!((a00 - 4.0).abs() < 1e-12 && (a10 - 2.0).abs() < 1e-12 && (a11 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn chol_solve_solves() {
        let a = vec![vec![4.0, 2.0], vec![2.0, 3.0]];
        let l = cholesky(&a).unwrap();
        let x = chol_solve(&l, &[10.0, 8.0]);
        assert!((4.0 * x[0] + 2.0 * x[1] - 10.0).abs() < 1e-9);
        assert!((2.0 * x[0] + 3.0 * x[1] - 8.0).abs() < 1e-9);
    }

    #[test]
    fn cdf_sane() {
        assert!((cdf(0.0) - 0.5).abs() < 1e-6);
        assert!(cdf(3.0) > 0.99 && cdf(-3.0) < 0.01);
    }

    #[test]
    fn bo_finds_peak_of_smooth_function() {
        // f(x) = -(x-0.7)^2: peak at 0.7.
        let mut bo = GpOptimizer::new(1, 7);
        for _ in 0..30 {
            let x = bo.suggest();
            let y = -(x[0] - 0.7) * (x[0] - 0.7);
            bo.observe(x, y);
        }
        let (bx, _) = bo.best().unwrap();
        assert!((bx[0] - 0.7).abs() < 0.15, "{bx:?}");
    }

    #[test]
    fn bo_beats_its_own_random_phase() {
        let mut bo = GpOptimizer::new(2, 9);
        let f = |x: &[f64]| -((x[0] - 0.3) * (x[0] - 0.3) + (x[1] - 0.8) * (x[1] - 0.8));
        for _ in 0..40 {
            let x = bo.suggest();
            let y = f(&x);
            bo.observe(x, y);
        }
        let random_best = bo.ys[..4].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let overall_best = bo.best().unwrap().1;
        assert!(overall_best >= random_best);
    }
}
