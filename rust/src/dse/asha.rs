//! Adaptive ASHA — asynchronous successive halving (Li et al. 2020),
//! as used by Determined AI for the CNV (§3.2.1) and KWS (§3.4) scans.
//!
//! Rung r has budget `min_budget * eta^r`.  A configuration is promoted to
//! rung r+1 when it is in the top 1/eta of completed runs at rung r.  The
//! "asynchronous" part: a worker asking for a job always gets one — either
//! a promotion (if some config is promotable) or a fresh config at rung 0 —
//! so no straggler ever blocks the pool.  Here workers are simulated
//! sequentially, which preserves the promotion semantics exactly.

/// One evaluated configuration at some budget.
#[derive(Clone, Debug)]
pub struct Trial {
    pub config_id: usize,
    pub rung: usize,
    pub budget: u32,
    pub score: f64,
}

pub struct Asha {
    pub eta: usize,
    pub min_budget: u32,
    pub max_rung: usize,
    /// Completed trials per rung: (config_id, score).
    rungs: Vec<Vec<(usize, f64)>>,
    /// Configs already promoted out of each rung.
    promoted: Vec<Vec<usize>>,
    next_config: usize,
    pub max_configs: usize,
}

/// A job handed to a worker.
#[derive(Clone, Debug, PartialEq)]
pub struct Job {
    pub config_id: usize,
    pub rung: usize,
    pub budget: u32,
}

impl Asha {
    pub fn new(eta: usize, min_budget: u32, max_rung: usize, max_configs: usize) -> Self {
        Self {
            eta,
            min_budget,
            max_rung,
            rungs: vec![Vec::new(); max_rung + 1],
            promoted: vec![Vec::new(); max_rung + 1],
            next_config: 0,
            max_configs,
        }
    }

    pub fn budget_for(&self, rung: usize) -> u32 {
        self.min_budget * (self.eta as u32).pow(rung as u32)
    }

    /// Get the next job: a promotion if one is available, else a new config.
    pub fn next_job(&mut self) -> Option<Job> {
        // Look for promotable configs, top rung first (ASHA's rule).
        for rung in (0..self.max_rung).rev() {
            let done = &self.rungs[rung];
            let k = done.len() / self.eta;
            if k == 0 {
                continue;
            }
            let mut sorted: Vec<&(usize, f64)> = done.iter().collect();
            sorted.sort_by(|a, b| b.1.total_cmp(&a.1));
            for cand in sorted.iter().take(k) {
                if !self.promoted[rung].contains(&cand.0) {
                    let id = cand.0;
                    self.promoted[rung].push(id);
                    return Some(Job {
                        config_id: id,
                        rung: rung + 1,
                        budget: self.budget_for(rung + 1),
                    });
                }
            }
        }
        if self.next_config < self.max_configs {
            let id = self.next_config;
            self.next_config += 1;
            Some(Job { config_id: id, rung: 0, budget: self.budget_for(0) })
        } else {
            None
        }
    }

    pub fn report(&mut self, job: &Job, score: f64) {
        self.rungs[job.rung].push((job.config_id, score));
    }

    pub fn completed(&self) -> Vec<Trial> {
        let mut out = Vec::new();
        for (rung, done) in self.rungs.iter().enumerate() {
            for &(config_id, score) in done {
                out.push(Trial { config_id, rung, budget: self.budget_for(rung), score });
            }
        }
        out
    }

    /// Best config seen at the highest rung reached.
    pub fn best(&self) -> Option<Trial> {
        for rung in (0..=self.max_rung).rev() {
            if let Some(&(config_id, score)) = self.rungs[rung]
                .iter()
                .max_by(|a, b| a.1.total_cmp(&b.1))
            {
                return Some(Trial { config_id, rung, budget: self.budget_for(rung), score });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Config quality: pseudo-random per config (hash), with
    /// budget-dependent reveal — the realistic ASHA regime.  (A score
    /// monotone in arrival order makes every new config a global best,
    /// which async ASHA legitimately promotes every time.)
    fn score(config_id: usize, budget: u32) -> f64 {
        let q = (config_id as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .rotate_left(17) as f64
            / u64::MAX as f64;
        q * (1.0 - (-(budget as f64) / 8.0).exp())
    }

    fn run(max_configs: usize) -> Asha {
        let mut asha = Asha::new(4, 1, 3, max_configs);
        while let Some(job) = asha.next_job() {
            let s = score(job.config_id, job.budget);
            asha.report(&job, s);
        }
        asha
    }

    #[test]
    fn promotes_good_configs_to_top_rung() {
        let asha = run(64);
        let best = asha.best().unwrap();
        assert_eq!(best.rung, 3, "{best:?}");
        // The promoted winner must be among the truly-best configs: its
        // asymptotic quality (budget -> inf) should be near the maximum.
        let q = |id: usize| score(id, 1_000_000);
        let qmax = (0..64).map(q).fold(f64::NEG_INFINITY, f64::max);
        assert!(q(best.config_id) > 0.85 * qmax, "{best:?}");
    }

    #[test]
    fn rung_sizes_shrink_by_eta() {
        let asha = run(64);
        let sizes: Vec<usize> = asha.rungs.iter().map(|r| r.len()).collect();
        assert_eq!(sizes[0], 64);
        assert!(sizes[1] <= sizes[0] / 4 + 1, "{sizes:?}");
        assert!(sizes[2] <= sizes[1] / 4 + 2, "{sizes:?}");
    }

    #[test]
    fn budgets_scale_geometrically() {
        let asha = Asha::new(4, 2, 3, 10);
        assert_eq!(asha.budget_for(0), 2);
        assert_eq!(asha.budget_for(1), 8);
        assert_eq!(asha.budget_for(3), 128);
    }

    #[test]
    fn no_config_promoted_twice_from_same_rung() {
        let asha = run(32);
        for rung in &asha.promoted {
            let mut seen = rung.clone();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), rung.len());
        }
    }

    #[test]
    fn total_evaluations_bounded() {
        let asha = run(64);
        let total: usize = asha.rungs.iter().map(|r| r.len()).sum();
        // 64 rung-0 + at most 64*(1/4 + 1/16 + 1/64) promotions ≈ 85.
        assert!(total <= 64 + 16 + 4 + 1 + 3, "{total}");
    }
}
