//! Design-space exploration: the paper's three scans as runnable drivers.
//!
//! * [`run_ic_bo_scan`] — Fig. 2: Bayesian-optimization NAS over 1-, 2-,
//!   and 3-stack ResNet-style IC models (accuracy vs MFLOPs).
//! * [`run_cnv_asha_scan`] — Fig. 3: adaptive ASHA over CNV variants
//!   (accuracy vs inference cost C of eq. 2).
//!
//! Both use the calibrated surrogates in [`crate::surrogate`] (see
//! DESIGN.md for the substitution rationale).  The KWS quantization scan
//! (Fig. 4) trains real models through [`crate::runtime`] — see
//! `examples/kws_quant_scan.rs`.

pub mod asha;
pub mod bo;
pub mod space;

use crate::data::prng::SplitMix64;
use crate::surrogate;
use space::{CnvSpace, IcNasSpace};

/// One scan point for plotting (Fig. 2 axes).
#[derive(Clone, Debug)]
pub struct ScanPoint {
    pub mflops: f64,
    pub accuracy: f64,
    pub label: String,
}

/// Fig. 2: one BO scan of `budget` models at a fixed stack count.
pub fn run_ic_bo_scan(stacks: usize, budget: usize, seed: u64) -> Vec<ScanPoint> {
    let space = IcNasSpace { stacks };
    let mut bo = bo::GpOptimizer::new(space.dim(), seed);
    let mut points = Vec::with_capacity(budget);
    for _ in 0..budget {
        let x = bo.suggest();
        let cfg = space.decode(&x);
        let acc = surrogate::ic_nas_accuracy(
            stacks,
            cfg.mflops(),
            cfg.max_filters(),
            cfg.seed(),
        );
        points.push(ScanPoint {
            mflops: cfg.mflops(),
            accuracy: acc,
            label: format!(
                "{}stk f{:?} k{:?} s{:?}",
                stacks, cfg.filters, cfg.kernels, cfg.strides
            ),
        });
        bo.observe(x, acc);
    }
    points
}

/// One Fig. 3 scan point: accuracy vs inference cost at final rung.
#[derive(Clone, Debug)]
pub struct AshaPoint {
    pub inference_cost: f64,
    pub accuracy: f64,
    pub budget_epochs: u32,
    pub rung: usize,
}

/// Fig. 3: adaptive ASHA over the CNV space.
pub fn run_cnv_asha_scan(max_configs: usize, seed: u64) -> Vec<AshaPoint> {
    let space = CnvSpace;
    let reference = space.reference();
    let mut rng = SplitMix64::new(seed);
    let configs: Vec<space::CnvConfig> =
        (0..max_configs).map(|_| space.sample(&mut rng)).collect();
    // eta=4 rungs: 1, 4, 16, 64 epochs — "up to 100 epochs" with early stop.
    let mut asha = asha::Asha::new(4, 1, 3, max_configs);
    let mut points = Vec::new();
    while let Some(job) = asha.next_job() {
        let cfg = &configs[job.config_id];
        let c = cfg.inference_cost(&reference);
        let acc = surrogate::cnv_asha_accuracy(c, cfg.weight_bits, job.budget, cfg.seed());
        asha.report(&job, acc);
        points.push(AshaPoint {
            inference_cost: c,
            accuracy: acc,
            budget_epochs: job.budget,
            rung: job.rung,
        });
    }
    points
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_scan_produces_pareto_shape() {
        let pts = run_ic_bo_scan(2, 100, 11);
        assert_eq!(pts.len(), 100);
        // The best accuracy must come from a non-trivial FLOPs model.
        let best = pts.iter().max_by(|a, b| a.accuracy.total_cmp(&b.accuracy)).unwrap();
        assert!(best.accuracy > 68.0, "{best:?}");
        // And small models must exist with lower accuracy (Pareto spread).
        let min_acc = pts.iter().map(|p| p.accuracy).fold(f64::INFINITY, f64::min);
        assert!(best.accuracy - min_acc > 10.0);
    }

    #[test]
    fn fig2_bo_average_improves_over_time() {
        let pts = run_ic_bo_scan(2, 60, 3);
        let first: f64 = pts[..15].iter().map(|p| p.accuracy).sum::<f64>() / 15.0;
        let last: f64 = pts[45..].iter().map(|p| p.accuracy).sum::<f64>() / 15.0;
        assert!(last > first - 1.0, "first={first} last={last}");
        let best_late = pts[15..].iter().map(|p| p.accuracy).fold(f64::NEG_INFINITY, f64::max);
        let best_early = pts[..15].iter().map(|p| p.accuracy).fold(f64::NEG_INFINITY, f64::max);
        assert!(best_late >= best_early - 2.0);
    }

    #[test]
    fn fig3_reference_is_near_optimal() {
        // Paper: "the CNV-W1A1 model performs near optimally" — configs
        // with C < 1 shouldn't dominate it.
        let pts = run_cnv_asha_scan(64, 5);
        let ref_acc = surrogate::cnv_asha_accuracy(1.0, 1, 64, 0);
        let cheaper_better = pts
            .iter()
            .filter(|p| p.inference_cost < 0.8 && p.accuracy > ref_acc + 1.0)
            .count();
        assert_eq!(cheaper_better, 0, "{cheaper_better} cheap configs beat the reference");
    }

    #[test]
    fn fig3_high_rungs_get_high_budget() {
        let pts = run_cnv_asha_scan(64, 6);
        assert!(pts.iter().any(|p| p.rung >= 2));
        for p in &pts {
            assert_eq!(p.budget_epochs, 4u32.pow(p.rung as u32));
        }
    }
}
