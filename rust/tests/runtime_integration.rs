//! Integration: AOT artifacts -> PJRT runtime -> inference + training.
//!
//! These tests need `make artifacts` to have run; they are skipped (with a
//! message) otherwise, so `cargo test` stays green on a fresh checkout.

use tinyml_codesign::coordinator::{self, TrainConfig};
use tinyml_codesign::data;
use tinyml_codesign::runtime::{LoadedModel, Runtime};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = tinyml_codesign::artifacts_dir();
    if dir.join("index.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn kws_fwd1_runs_and_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut m = LoadedModel::load(&dir, "kws_mlp_w3a3").unwrap();
    let ts = data::test_set("kws", 4, 1);
    let a = m.infer1(&rt, &ts.samples[0].x).unwrap();
    let b = m.infer1(&rt, &ts.samples[0].x).unwrap();
    assert_eq!(a.len(), 12);
    assert_eq!(a, b);
    // Different inputs give different logits.
    let c = m.infer1(&rt, &ts.samples[1].x).unwrap();
    assert_ne!(a, c);
}

#[test]
fn kws_train_step_reduces_loss_and_updates_params() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut m = LoadedModel::load(&dir, "kws_mlp_w3a3").unwrap();
    let batch = m.ensure_train(&rt).unwrap();
    let mut rng = data::prng::SplitMix64::new(7);
    let (x, y) = data::train_batch("kws", &mut rng, batch);
    let first = m.train_step(&rt, &x, &y, 0.05).unwrap();
    let mut last = first;
    for _ in 0..5 {
        last = m.train_step(&rt, &x, &y, 0.05).unwrap();
    }
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}

#[test]
fn ad_anomaly_scores_separate_after_training() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut m = LoadedModel::load(&dir, "ad_autoencoder").unwrap();
    let cfg = TrainConfig { steps: 60, lr: 0.05, final_lr_frac: 0.3, log_every: 20, seed: 3 };
    let curve = coordinator::train(&rt, &mut m, &cfg).unwrap();
    assert!(curve.last().unwrap().loss < curve.first().unwrap().loss);
    let auc = coordinator::evaluate(&rt, &mut m, 60, 11).unwrap();
    assert!(auc > 0.6, "AUC after short training: {auc}");
}

#[test]
fn batch_fwd_matches_fwd1() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut m = LoadedModel::load(&dir, "kws_mlp_w3a3").unwrap();
    let batch = m.ensure_fwd_batch(&rt).unwrap();
    let ts = data::test_set("kws", batch, 5);
    let feat = m.manifest.input_elems();
    let mut x = vec![0.0f32; batch * feat];
    for (i, s) in ts.samples.iter().enumerate() {
        x[i * feat..(i + 1) * feat].copy_from_slice(&s.x);
    }
    let out = m.infer_batch(&rt, &x).unwrap();
    let single = m.infer1(&rt, &ts.samples[0].x).unwrap();
    for (a, b) in out[..12].iter().zip(&single) {
        assert!((a - b).abs() < 1e-4, "batch vs single mismatch: {a} {b}");
    }
}
