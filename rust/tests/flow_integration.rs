//! Integration: real AOT topologies -> passes -> schedule -> FIFO opt ->
//! resources -> power, asserting the paper's qualitative claims
//! (Tables 2-5 shapes).  Needs `make artifacts`.

use tinyml_codesign::board::{arty_a7_100t, pynq_z2};
use tinyml_codesign::coordinator::flow::{run_flow, FlowOptions};
use tinyml_codesign::dataflow::schedule::ScheduleConfig;
use tinyml_codesign::ir::Graph;
use tinyml_codesign::metrics;
use tinyml_codesign::report::tables;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = tinyml_codesign::artifacts_dir();
    if dir.join("index.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built");
        None
    }
}

fn load(name: &str) -> Option<Graph> {
    let dir = artifacts()?;
    Some(Graph::load(&dir.join(format!("{name}_topology.json"))).unwrap())
}

#[test]
fn all_exported_topologies_validate_and_flow() {
    let Some(dir) = artifacts() else { return };
    let board = pynq_z2();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if let Some(model) = name.strip_suffix("_topology.json") {
            let g = Graph::load(&path).unwrap();
            let r = run_flow(&g, &board, &FlowOptions::default(), &ScheduleConfig::default())
                .unwrap_or_else(|e| panic!("{model}: {e}"));
            assert!(!r.fifo.sizing_run.deadlocked, "{model} deadlocked");
            assert!(r.latency_cycles > 0, "{model}");
        }
    }
}

#[test]
fn table5_shape_finn_ic_much_faster_than_hls4ml_ic() {
    let Some(dir) = artifacts() else { return };
    let board = pynq_z2();
    let h = tables::flow_for(&dir, "ic_hls4ml", &board).unwrap();
    let f = tables::flow_for(&dir, "ic_finn_full", &board).unwrap();
    // Paper: 27.3 ms vs 1.5 ms (18.2x).  Assert >4x and the BRAM trade.
    let ratio = h.latency_s / f.latency_s;
    assert!(ratio > 4.0, "latency ratio {ratio}");
    assert!(
        h.resources.total.bram36 < f.resources.total.bram36,
        "hls4ml should use fewer BRAMs: {} vs {}",
        h.resources.total.bram36,
        f.resources.total.bram36
    );
}

#[test]
fn table5_shape_ad_kws_are_microsecond_class() {
    let Some(dir) = artifacts() else { return };
    for board in [pynq_z2(), arty_a7_100t()] {
        for name in ["ad_autoencoder", "kws_mlp_w3a3"] {
            let r = tables::flow_for(&dir, name, &board).unwrap();
            assert!(
                r.latency_s < 500e-6,
                "{name} on {}: {} s",
                board.name,
                r.latency_s
            );
            assert!(r.fits, "{name} must fit on {}", board.name);
            // Paper band: tens of uJ (30-100).
            assert!(
                (3.0..2000.0).contains(&r.energy_per_inference_uj),
                "{name} energy {}",
                r.energy_per_inference_uj
            );
        }
    }
}

#[test]
fn table4_shape_reference_unsynthesizable_final_fits() {
    let Some(_) = artifacts() else { return };
    let board = pynq_z2();
    let reference = load("ad_reference").unwrap();
    let final_g = load("ad_autoencoder").unwrap();
    let cfg = ScheduleConfig::default();
    let r_ref = run_flow(&reference, &board, &FlowOptions::default(), &cfg).unwrap();
    let r_fin = run_flow(&final_g, &board, &FlowOptions::default(), &cfg).unwrap();
    assert!(!r_ref.fits, "fp32 reference must NOT fit: {:?}", r_ref.resources.total);
    assert!(r_fin.fits, "submitted AD must fit: {:?}", r_fin.resources.total);
    // LUT trend of Table 4: folded-640 >> downsampled >> final.
    let folded = run_flow(&load("ad_folded").unwrap(), &board, &FlowOptions::default(), &cfg).unwrap();
    let down = run_flow(&load("ad_downsampled").unwrap(), &board, &FlowOptions::default(), &cfg).unwrap();
    assert!(folded.resources.accelerator.luts > down.resources.accelerator.luts);
    assert!(down.resources.accelerator.luts > r_fin.resources.accelerator.luts);
}

#[test]
fn table3_shape_fifo_opt_cuts_bram_relu_merge_cuts_lut() {
    let Some(_) = artifacts() else { return };
    let g = load("ic_hls4ml").unwrap();
    let board = pynq_z2();
    let cfg = ScheduleConfig::default();
    let none = FlowOptions { run_passes: true, fifo_opt: false, relu_merge: false, bn_fold: true };
    let fifo = FlowOptions { run_passes: true, fifo_opt: true, relu_merge: false, bn_fold: true };
    let relu = FlowOptions { run_passes: true, fifo_opt: false, relu_merge: true, bn_fold: true };
    let all = FlowOptions::default();
    let r0 = run_flow(&g, &board, &none, &cfg).unwrap();
    let rf = run_flow(&g, &board, &fifo, &cfg).unwrap();
    let rr = run_flow(&g, &board, &relu, &cfg).unwrap();
    let ra = run_flow(&g, &board, &all, &cfg).unwrap();
    assert!(
        rf.resources.accelerator.bram36 < r0.resources.accelerator.bram36,
        "FIFO opt must cut BRAM: {} -> {}",
        r0.resources.accelerator.bram36,
        rf.resources.accelerator.bram36
    );
    assert!(
        rr.resources.accelerator.luts < r0.resources.accelerator.luts,
        "ReLU merge must cut LUTs: {} -> {}",
        r0.resources.accelerator.luts,
        rr.resources.accelerator.luts
    );
    // All-opt may trade a little LUT (LUTRAM FIFOs) for the BRAM cut, so
    // allow slack; it must stay within a whisker of the best single opt.
    assert!(ra.resources.accelerator.luts <= rr.resources.accelerator.luts * 1.2);
    assert!(ra.resources.accelerator.bram36 <= rf.resources.accelerator.bram36 * 1.1);
}

#[test]
fn table2_shape_fifo_policies() {
    let Some(dir) = artifacts() else { return };
    let board = pynq_z2();
    // FINN KWS: depths must be powers of two.
    let r = tables::flow_for(&dir, "kws_mlp_w3a3", &board).unwrap();
    assert!(r.fifo.depths.iter().all(|d| d.is_power_of_two()), "{:?}", r.fifo.depths);
    // hls4ml IC: arbitrary integers allowed, wide range.
    let h = tables::flow_for(&dir, "ic_hls4ml", &board).unwrap();
    assert!(h.fifo_range.1 > h.fifo_range.0, "{:?}", h.fifo_range);
}

#[test]
fn kws_cost_metrics_are_monotone_in_bits() {
    let Some(dir) = artifacts() else { return };
    let costs = tables::fig4_costs(&dir).unwrap();
    // BOPs must rise with precision: w1a1 < w2a2 < w3a3 < w4a4 < w8a8.
    for w in costs.windows(2).take(4) {
        assert!(w[1].1 > w[0].1, "{:?} !< {:?}", w[0], w[1]);
    }
    // WM bits exactly: 259584 * wbits.
    assert_eq!(costs[2].2, 259_584.0 * 3.0);
}

#[test]
fn full_cnv_metrics_match_table1_scale() {
    let Some(_) = artifacts() else { return };
    let g = load("ic_finn_full").unwrap();
    let weights: u64 = g.compute_nodes().map(|n| n.params()).sum();
    assert!((weights as f64 - 1_542_848.0).abs() / 1_542_848.0 < 0.06, "{weights}");
    let mflops = metrics::flops(&g) as f64 / 1e6;
    assert!((50.0..300.0).contains(&mflops), "{mflops}");
}

#[test]
fn eembc_end_to_end_on_flow_numbers() {
    use tinyml_codesign::data;
    use tinyml_codesign::eembc::{DesignPerf, Dut, Runner};
    use tinyml_codesign::runtime::{LoadedModel, Runtime};
    let Some(dir) = artifacts() else { return };
    let board = pynq_z2();
    let fr = tables::flow_for(&dir, "kws_mlp_w3a3", &board).unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut m = LoadedModel::load(&dir, "kws_mlp_w3a3").unwrap();
    let samples = data::test_set("kws", 24, 0xBEEF);
    let mut dut = Dut::new(&mut m, DesignPerf { latency_s: fr.latency_s, power_w: fr.power_w });
    let runner = Runner { min_window_s: 0.05, ..Default::default() };
    let perf = runner.performance_mode(&rt, &mut dut, &samples.samples).unwrap();
    assert!((perf.median_latency_s - fr.latency_s).abs() / fr.latency_s < 1e-6);
    let energy = runner.energy_mode(&rt, &mut dut, &samples.samples).unwrap();
    let expect = fr.power_w * fr.latency_s * 1e6;
    assert!((energy.median_energy_uj - expect).abs() / expect < 0.05, "{energy:?}");
    let acc = runner.accuracy_mode(&rt, &mut dut, &samples.samples).unwrap();
    assert_eq!(acc.metric, "top1");
    assert_eq!(acc.n_samples, 24);
}
