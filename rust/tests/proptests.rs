//! Property-based tests over coordinator invariants.
//!
//! The offline vendored crate set has no proptest, so properties are
//! checked with an in-tree randomized harness driven by the shared
//! splitmix64 stream: hundreds of random cases per property, fully
//! deterministic (failures print the case seed for replay).

use tinyml_codesign::coordinator::engine::{BatchExecutor, BatchPolicy, ModelExecutor};
use tinyml_codesign::coordinator::pool::{PooledVec, ReplyPool, POISON_BITS};
use tinyml_codesign::data::prng::SplitMix64;
use tinyml_codesign::dataflow::{Prereq, Simulator, StageSpec, UNBOUNDED_DEPTH};
use tinyml_codesign::fifo::{optimize_fifos, DepthPolicy};
use tinyml_codesign::fleet::worker::run_worker;
use tinyml_codesign::fleet::{
    BoardInstance, BoardQueue, BreakerConfig, ChaosSpec, DeadlineStats, Fleet,
    FleetConfig, FleetError, FleetRequest, HealthConfig, PeerList, Policy, Priority,
    Registry, RequestTag, RouteError, Router, SimBoardExecutor, Telemetry,
    WorkerConfig,
};
use tinyml_codesign::ir::Graph;
use tinyml_codesign::kernels::{
    quantized_max_abs_error, simd, PackedLinear, ScratchArena, SmoothKernel,
};
use tinyml_codesign::passes;

/// Random chain of dataflow stages with consistent token counts.
fn random_chain(rng: &mut SplitMix64) -> Vec<StageSpec> {
    let n_stages = 1 + rng.next_below(5) as usize;
    let mut tokens = 4 + rng.next_below(60) as usize;
    let mut stages = Vec::new();
    for i in 0..n_stages {
        let kind = rng.next_below(3);
        let (n_out, prereq) = match kind {
            0 => (tokens, Prereq::Elementwise),
            1 => {
                let n_out = 1 + rng.next_below(8) as usize;
                (n_out, Prereq::All)
            }
            _ => {
                // Window over a square raster if tokens is a square; else
                // fall back to elementwise.
                let w = (tokens as f64).sqrt() as usize;
                if w >= 3 && w * w == tokens {
                    let k = 2 + rng.next_below(2) as usize;
                    let out_w = w - k + 1;
                    (out_w * out_w, Prereq::Window { in_w: w, kernel: k, stride: 1, pad: 0 })
                } else {
                    (tokens, Prereq::Elementwise)
                }
            }
        };
        stages.push(StageSpec {
            name: format!("s{i}"),
            n_in: tokens,
            n_out,
            ii_out: 1 + rng.next_below(6),
            ii_in: 1 + rng.next_below(3),
            prereq,
        });
        tokens = n_out;
    }
    stages
}

#[test]
fn prop_sized_fifos_never_deadlock_and_preserve_latency() {
    let mut rng = SplitMix64::new(0xF1F0);
    for case in 0..150 {
        let stages = random_chain(&mut rng);
        let sim = Simulator::new(stages);
        let opt = optimize_fifos(&sim, DepthPolicy::Exact);
        assert!(!opt.sizing_run.deadlocked, "case {case}: sizing deadlocked");
        let replay = sim.run(&opt.depths, 1);
        assert!(!replay.deadlocked, "case {case}: sized run deadlocked");
        assert_eq!(
            replay.latency_cycles, opt.unoptimized_latency,
            "case {case}: latency changed by sizing"
        );
    }
}

#[test]
fn prop_fifo_occupancy_never_exceeds_depth() {
    let mut rng = SplitMix64::new(0x0CC0);
    for case in 0..100 {
        let stages = random_chain(&mut rng);
        let sim = Simulator::new(stages);
        let depth = 1 + rng.next_below(6) as usize;
        let depths = vec![depth; sim.stages.len() + 1];
        let r = sim.run(&depths, 1);
        assert!(!r.deadlocked, "case {case}");
        assert!(
            r.fifo_max_occupancy.iter().all(|&m| m <= depth),
            "case {case}: occupancy {:?} exceeded depth {depth}",
            r.fifo_max_occupancy
        );
    }
}

#[test]
fn prop_latency_monotone_in_fifo_depth() {
    let mut rng = SplitMix64::new(0x10A7);
    for case in 0..60 {
        let stages = random_chain(&mut rng);
        let sim = Simulator::new(stages);
        let tight = sim.run(&vec![1; sim.stages.len() + 1], 1);
        let roomy = sim.run(&vec![UNBOUNDED_DEPTH; sim.stages.len() + 1], 1);
        assert!(!tight.deadlocked && !roomy.deadlocked, "case {case}");
        assert!(
            tight.latency_cycles >= roomy.latency_cycles,
            "case {case}: deeper FIFOs made it slower ({} < {})",
            tight.latency_cycles,
            roomy.latency_cycles
        );
    }
}

/// Random chain graphs for pass invariants.
fn random_graph(rng: &mut SplitMix64) -> Graph {
    let n_layers = 1 + rng.next_below(4) as usize;
    let mut dims = vec![4 + rng.next_below(60) as usize];
    for _ in 0..n_layers {
        dims.push(2 + rng.next_below(48) as usize);
    }
    let flow = if rng.next_f64() < 0.5 { "finn" } else { "hls4ml" };
    let mut nodes = Vec::new();
    for (i, w) in dims.windows(2).enumerate() {
        let params = w[0] * w[1];
        nodes.push(format!(
            r#"{{"op":"Dense","name":"fc{i}","in_features":{},"out_features":{},"weight_bits":{},"params":{params}}}"#,
            w[0],
            w[1],
            1 + rng.next_below(8)
        ));
        if rng.next_f64() < 0.8 {
            nodes.push(format!(
                r#"{{"op":"BatchNorm","name":"bn{i}","channels":{},"params":{}}}"#,
                w[1],
                4 * w[1]
            ));
        }
        if i + 1 < dims.len() - 1 {
            if rng.next_f64() < 0.5 {
                nodes.push(format!(
                    r#"{{"op":"ReLU","name":"r{i}","channels":{},"act_bits":{},"params":0}}"#,
                    w[1],
                    2 + rng.next_below(7)
                ));
            } else {
                nodes.push(format!(
                    r#"{{"op":"BipolarAct","name":"b{i}","channels":{},"params":0}}"#,
                    w[1]
                ));
            }
        }
    }
    let total: u64 = dims
        .windows(2)
        .map(|w| (w[0] * w[1]) as u64)
        .sum::<u64>()
        + nodes
            .iter()
            .filter(|n| n.contains("BatchNorm"))
            .map(|n| {
                let c: u64 = n
                    .split("\"channels\":")
                    .nth(1)
                    .unwrap()
                    .split(',')
                    .next()
                    .unwrap()
                    .parse()
                    .unwrap();
                4 * c
            })
            .sum::<u64>();
    let json = format!(
        r#"{{"name":"rand","task":"kws","flow":"{flow}","input_shape":[{}],"input_bits":8,"nodes":[{}],"total_params":{total}}}"#,
        dims[0],
        nodes.join(",")
    );
    Graph::from_json_str(&json).unwrap()
}

#[test]
fn prop_passes_preserve_validity_and_are_idempotent() {
    let mut rng = SplitMix64::new(0x9A55);
    let pass_list: [(&str, fn(&Graph) -> Graph); 5] = [
        ("fold_flatten", passes::fold_flatten),
        ("fold_bn", passes::fold_bn_into_linear),
        ("merge_relu", passes::merge_relu),
        ("streamline", passes::streamline),
        ("topk", passes::remove_softmax_insert_topk),
    ];
    for case in 0..120 {
        let g = random_graph(&mut rng);
        for (name, pass) in pass_list {
            let once = pass(&g);
            once.validate().unwrap_or_else(|e| panic!("case {case} {name}: {e}"));
            let twice = pass(&once);
            assert_eq!(once.nodes, twice.nodes, "case {case}: {name} not idempotent");
        }
    }
}

#[test]
fn prop_streamline_conserves_compute_nodes() {
    let mut rng = SplitMix64::new(0x57E4);
    for case in 0..100 {
        let g = random_graph(&mut rng);
        let s = passes::streamline(&g);
        assert_eq!(
            g.compute_nodes().count(),
            s.compute_nodes().count(),
            "case {case}: streamlining changed compute nodes"
        );
        assert_eq!(g.total_macs(), s.total_macs(), "case {case}");
    }
}

#[test]
fn prop_accumulator_minimization_is_sound() {
    // acc_bits must be large enough that a worst-case dot product cannot
    // overflow: wbits + in_bits + ceil(log2(fan_in)) >= exact bound.
    let mut rng = SplitMix64::new(0xACC5);
    for case in 0..100 {
        let g = passes::minimize_accumulators(&passes::infer_datatypes(&random_graph(&mut rng)));
        for n in g.compute_nodes() {
            if let tinyml_codesign::ir::Node::Dense {
                acc_bits, weight_bits, in_bits, in_features, ..
            } = n
            {
                // Worst case |sum| < 2^(wbits-1) * 2^in_bits * fan_in.
                let need =
                    (*weight_bits + *in_bits) as f64 + (*in_features as f64).log2();
                assert!(
                    *acc_bits as f64 >= need,
                    "case {case}: acc {acc_bits} < bound {need}"
                );
                assert!(*acc_bits <= 64, "case {case}");
            }
        }
    }
}

#[test]
fn prop_bops_monotone_in_weight_bits() {
    use tinyml_codesign::metrics::bops;
    let mut rng = SplitMix64::new(0xB095);
    for _ in 0..60 {
        let g = random_graph(&mut rng);
        let mut hi = g.clone();
        for n in &mut hi.nodes {
            if let tinyml_codesign::ir::Node::Dense { weight_bits, .. } = n {
                *weight_bits += 4;
            }
        }
        assert!(bops(&hi) > bops(&g));
    }
}

// ---------------------------------------------------------------------------
// Fleet router properties.
// ---------------------------------------------------------------------------

const TASKS: [&str; 3] = ["kws", "ad", "ic"];

/// Random heterogeneous registry: 2-8 instances over random tasks.
fn random_registry(rng: &mut SplitMix64) -> Registry {
    let n = 2 + rng.next_below(7) as usize;
    let instances = (0..n)
        .map(|id| {
            let task = TASKS[rng.next_below(3) as usize];
            let latency_us = 20.0 + rng.next_f64() * 2000.0;
            let ii_us = latency_us / (2.0 + rng.next_f64() * 18.0);
            let power_w = 1.2 + rng.next_f64();
            BoardInstance::synthetic(id, task, latency_us, ii_us, power_w)
        })
        .collect();
    Registry { instances }
}

fn random_policy(rng: &mut SplitMix64) -> Policy {
    match rng.next_below(4) {
        0 => Policy::RoundRobin,
        1 => Policy::LeastLoaded,
        2 => Policy::EnergyAware,
        _ => Policy::LatencySlo { slo_us: 100.0 + rng.next_f64() * 20_000.0 },
    }
}

#[test]
fn prop_router_only_routes_to_boards_hosting_the_task() {
    let mut rng = SplitMix64::new(0xF1EE_0001);
    for case in 0..200 {
        let reg = random_registry(&mut rng);
        let policy = random_policy(&mut rng);
        let cap = 1 + rng.next_below(8) as usize;
        let router = Router::new(&reg, policy, cap);
        let depths: Vec<usize> =
            (0..reg.len()).map(|_| rng.next_below(cap as u64 + 1) as usize).collect();
        let task = TASKS[rng.next_below(3) as usize];
        let eligible = reg.eligible(task);
        match router.select(task, &depths) {
            Ok(i) => {
                assert_eq!(
                    reg.instances[i].task, task,
                    "case {case} ({policy:?}): routed {task} to {}",
                    reg.instances[i].label
                );
                assert!(depths[i] < cap, "case {case}: routed to a full queue");
            }
            Err(RouteError::UnknownTask) => {
                assert!(eligible.is_empty(), "case {case}: spurious UnknownTask");
            }
            Err(RouteError::Overloaded) => {
                assert!(
                    !eligible.is_empty() && eligible.iter().all(|&i| depths[i] >= cap),
                    "case {case}: spurious Overloaded with depths {depths:?}"
                );
            }
            Err(RouteError::SloUnattainable) => {
                assert!(
                    matches!(policy, Policy::LatencySlo { .. }),
                    "case {case}: {policy:?} returned SloUnattainable"
                );
            }
            // The pure router never sees the request payload or its
            // deadline — those refusals belong to the submit path.
            Err(e @ (RouteError::InvalidInput { .. } | RouteError::DeadlineUnmeetable)) => {
                panic!("case {case}: router returned a submit-side refusal {e:?}");
            }
        }
    }
}

#[test]
fn prop_router_respects_queue_bounds_and_drops_nothing() {
    // Drive a random admit/complete schedule against the pure router and
    // check conservation: everything admitted is either completed or
    // still queued, and no queue ever exceeds its bound.
    let mut rng = SplitMix64::new(0xF1EE_0002);
    for case in 0..120 {
        let reg = random_registry(&mut rng);
        let policy = random_policy(&mut rng);
        let cap = 1 + rng.next_below(6) as usize;
        let router = Router::new(&reg, policy, cap);
        let mut depths = vec![0usize; reg.len()];
        let (mut admitted, mut completed, mut rejected) = (0u64, 0u64, 0u64);
        for _ in 0..300 {
            if rng.next_f64() < 0.6 {
                let task = TASKS[rng.next_below(3) as usize];
                match router.select(task, &depths) {
                    Ok(i) => {
                        assert!(depths[i] < cap, "case {case}: admitted past the bound");
                        depths[i] += 1;
                        admitted += 1;
                    }
                    Err(_) => rejected += 1,
                }
            } else {
                // A worker finishes one queued request somewhere.
                let busy: Vec<usize> =
                    (0..reg.len()).filter(|&i| depths[i] > 0).collect();
                if !busy.is_empty() {
                    let i = busy[rng.next_below(busy.len() as u64) as usize];
                    depths[i] -= 1;
                    completed += 1;
                }
            }
            assert!(
                depths.iter().all(|&d| d <= cap),
                "case {case}: depths {depths:?} exceed cap {cap}"
            );
        }
        let queued: u64 = depths.iter().map(|&d| d as u64).sum();
        assert_eq!(
            admitted,
            completed + queued,
            "case {case} ({policy:?}): {admitted} admitted != {completed} completed \
             + {queued} queued ({rejected} rejected)"
        );
    }
}

#[test]
fn prop_round_robin_spreads_evenly_over_replicas() {
    let mut rng = SplitMix64::new(0xF1EE_0003);
    for case in 0..60 {
        let n = 2 + rng.next_below(4) as usize;
        let reg = Registry {
            instances: (0..n)
                .map(|id| {
                    BoardInstance::synthetic(
                        id,
                        "kws",
                        50.0 + rng.next_f64() * 500.0,
                        10.0,
                        1.5,
                    )
                })
                .collect(),
        };
        let rounds = 3 + rng.next_below(5) as usize;
        let router = Router::new(&reg, Policy::RoundRobin, n * rounds + 1);
        let mut counts = vec![0usize; n];
        let mut depths = vec![0usize; n];
        for _ in 0..n * rounds {
            let i = router.select("kws", &depths).unwrap();
            counts[i] += 1;
            depths[i] += 1;
        }
        let (lo, hi) =
            (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(
            hi - lo <= 1,
            "case {case}: round-robin skew {counts:?} over {n} replicas"
        );
    }
}

#[test]
fn fleet_end_to_end_delivers_every_admitted_request() {
    // Live fleet over synthetic boards: every admitted request must come
    // back, under every policy, with stealing on and off.
    let mut rng = SplitMix64::new(0xF1EE_0004);
    let policies = [
        Policy::RoundRobin,
        Policy::LeastLoaded,
        Policy::EnergyAware,
        Policy::LatencySlo { slo_us: 1e9 },
    ];
    for (pi, policy) in policies.into_iter().enumerate() {
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5),
                BoardInstance::synthetic(1, "kws", 250.0, 50.0, 1.8),
                BoardInstance::synthetic(2, "ad", 40.0, 5.0, 1.5),
                BoardInstance::synthetic(3, "ic", 300.0, 60.0, 1.6),
            ],
        };
        let cfg = FleetConfig {
            policy,
            work_stealing: pi % 2 == 0,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let n = 100;
        let mut pending = Vec::new();
        for _ in 0..n {
            let task = TASKS[rng.next_below(3) as usize];
            let x = vec![0.1f32; tinyml_codesign::data::feature_dim(task)];
            loop {
                match handle.submit(task, x.clone()) {
                    Ok(rx) => {
                        pending.push(rx);
                        break;
                    }
                    Err(RouteError::Overloaded) => {
                        std::thread::sleep(std::time::Duration::from_micros(100));
                    }
                    Err(e) => panic!("{policy:?}: unexpected rejection {e:?}"),
                }
            }
        }
        for rx in &pending {
            rx.recv()
                .expect("admitted request was dropped")
                .expect("request must not fail without chaos");
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served as usize, n, "{policy:?}");
        assert_eq!(
            summary.served_per_worker.iter().sum::<u64>() as usize,
            n,
            "{policy:?}"
        );
    }
}

#[test]
fn prop_chaos_every_admitted_request_gets_exactly_one_outcome() {
    // Under a random fault plan — transient exec errors on every
    // replica, permanent death / injected panics / stalls on replica 0
    // — every admitted request must resolve with *exactly one* outcome:
    // a reply or a typed FleetError.  Never a hang (recv_timeout), never
    // a duplicate (the channel must be spent after the first outcome).
    let mut rng = SplitMix64::new(0xC4A0_5007);
    for case in 0..6u64 {
        let mut clauses: Vec<String> = Vec::new();
        let exec_p = [0.0, 0.15, 0.4][rng.next_below(3) as usize];
        if exec_p > 0.0 {
            clauses.push(format!("exec={exec_p}"));
        }
        // Targeted faults hit replica 0 only, so its kws sibling keeps
        // the plan survivable (mirrors FaultPlan::materialize's own
        // kill=fastest rule).
        if rng.next_below(2) == 0 {
            clauses.push("kill=0@3".to_string());
        } else if rng.next_below(2) == 0 {
            clauses.push("panic=0@4".to_string());
        }
        if rng.next_below(2) == 0 {
            clauses.push("stall=200@4".to_string());
        }
        let spec =
            ChaosSpec::parse(&clauses.join(","), 0x51EE ^ (case << 8)).unwrap();
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5),
                BoardInstance::synthetic(1, "kws", 250.0, 50.0, 1.8),
            ],
        };
        let cfg = FleetConfig {
            queue_cap: 1024,
            chaos: Some(spec),
            health: Some(HealthConfig {
                interval: std::time::Duration::from_millis(1),
                max_consecutive_failures: 2,
                ..Default::default()
            }),
            // Outlast the window where a dying replica can steal a
            // request back and fail it again before ejection lands.
            retry_budget: 50,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let n = 60;
        let x = vec![0.1f32; tinyml_codesign::data::feature_dim("kws")];
        let mut pending = Vec::new();
        for _ in 0..n {
            match handle.submit("kws", x.clone()) {
                Ok(rx) => pending.push(rx),
                Err(e) => panic!("case {case} ({spec:?}): rejected: {e:?}"),
            }
        }
        let (mut ok, mut typed_err) = (0usize, 0usize);
        for rx in &pending {
            match rx.recv_timeout(std::time::Duration::from_secs(10)) {
                Ok(Ok(_)) => ok += 1,
                Ok(Err(FleetError::Exhausted { attempts })) => {
                    assert!(attempts > 0, "case {case}: exhausted with 0 attempts");
                    typed_err += 1;
                }
                Err(e) => panic!(
                    "case {case} ({spec:?}): request hung or was dropped: {e:?}"
                ),
            }
            // Exactly one outcome: the reply channel must be spent.
            assert!(
                rx.try_recv().is_err(),
                "case {case} ({spec:?}): duplicate outcome on one request"
            );
        }
        assert_eq!(ok + typed_err, n, "case {case}");
        let summary = fleet.shutdown();
        assert_eq!(
            summary.snapshot.served as usize, ok,
            "case {case} ({spec:?}): telemetry served must match delivered \
             replies exactly (no double-serving)"
        );
    }
}

#[test]
fn prop_chaos_with_coalescing_still_yields_exactly_one_outcome_each() {
    // The exactly-one-outcome invariant must survive single-flight
    // coalescing: every submit here carries the *same* input, so almost
    // all requests ride another request's flight, and a chaos-failed
    // leader must fan its typed error to every follower — never a hang,
    // never a duplicate outcome, and every delivered Ok accounted as
    // either a board-served leader or a fanned copy.
    let mut rng = SplitMix64::new(0xC0A1_E5CE);
    for case in 0..6u64 {
        let mut clauses: Vec<String> = Vec::new();
        let exec_p = [0.0, 0.15, 0.4][rng.next_below(3) as usize];
        if exec_p > 0.0 {
            clauses.push(format!("exec={exec_p}"));
        }
        if rng.next_below(2) == 0 {
            clauses.push("kill=0@3".to_string());
        } else if rng.next_below(2) == 0 {
            clauses.push("panic=0@4".to_string());
        }
        if rng.next_below(2) == 0 {
            clauses.push("stall=200@4".to_string());
        }
        let spec =
            ChaosSpec::parse(&clauses.join(","), 0xC0A1 ^ (case << 8)).unwrap();
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5),
                BoardInstance::synthetic(1, "kws", 250.0, 50.0, 1.8),
            ],
        };
        let cfg = FleetConfig {
            queue_cap: 1024,
            coalesce: true,
            chaos: Some(spec),
            health: Some(HealthConfig {
                interval: std::time::Duration::from_millis(1),
                max_consecutive_failures: 2,
                ..Default::default()
            }),
            retry_budget: 50,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let n = 60;
        let x = vec![0.1f32; tinyml_codesign::data::feature_dim("kws")];
        let mut pending = Vec::new();
        for _ in 0..n {
            match handle.submit("kws", x.clone()) {
                Ok(rx) => pending.push(rx),
                Err(e) => panic!("case {case} ({spec:?}): rejected: {e:?}"),
            }
        }
        let (mut ok, mut typed_err) = (0usize, 0usize);
        for rx in &pending {
            match rx.recv_timeout(std::time::Duration::from_secs(10)) {
                Ok(Ok(_)) => ok += 1,
                Ok(Err(FleetError::Exhausted { attempts })) => {
                    // Followers inherit the leader's terminal error with
                    // its real attempt count; `attempts: 0` only marks a
                    // leader refused at admission, which this queue_cap
                    // never produces.
                    assert!(attempts > 0, "case {case}: exhausted with 0 attempts");
                    typed_err += 1;
                }
                Err(e) => panic!(
                    "case {case} ({spec:?}): request hung or was dropped: {e:?}"
                ),
            }
            assert!(
                rx.try_recv().is_err(),
                "case {case} ({spec:?}): duplicate outcome on one request"
            );
        }
        assert_eq!(ok + typed_err, n, "case {case}");
        let summary = fleet.shutdown();
        let snap = &summary.snapshot;
        let co = snap.coalesce.clone().unwrap_or_default();
        assert_eq!(
            snap.served as usize + co.fanned_ok as usize,
            ok,
            "case {case} ({spec:?}): delivered Oks must be exactly the \
             board-served leaders plus the fanned follower copies"
        );
        assert_eq!(
            co.fanned_ok + co.fanned_err,
            co.followers,
            "case {case} ({spec:?}): every follower must resolve exactly once"
        );
    }
}

#[test]
fn prop_chaos_with_deadlines_hedging_and_breaker_yields_exactly_one_outcome() {
    // The whole robustness plane armed at once: random fault plans with
    // per-request deadlines, tail-latency hedging, and per-replica
    // circuit breakers.  Every admitted request must still resolve with
    // *exactly one* terminal outcome — a reply, a spent retry budget
    // (`Exhausted`), or a typed `DeadlineExceeded` — never a hang,
    // never a duplicate.  Hedged duplicate legs and breaker-masked
    // replicas must never leak an extra outcome into a caller's
    // channel, a deadline-free request must never expire, and no board
    // may ever execute a request that was already past its deadline.
    let mut rng = SplitMix64::new(0xD11E_5EED);
    for case in 0..6u64 {
        let mut clauses: Vec<String> = Vec::new();
        let exec_p = [0.0, 0.15, 0.4][rng.next_below(3) as usize];
        if exec_p > 0.0 {
            clauses.push(format!("exec={exec_p}"));
        }
        if rng.next_below(2) == 0 {
            clauses.push("kill=0@3".to_string());
        } else if rng.next_below(2) == 0 {
            clauses.push("panic=0@4".to_string());
        }
        // A slowdown feeds the drift EWMA, which is what arms hedging.
        if rng.next_below(2) == 0 {
            clauses.push("slow=4x0".to_string());
        }
        if rng.next_below(2) == 0 {
            clauses.push("stall=200@4".to_string());
        }
        let spec =
            ChaosSpec::parse(&clauses.join(","), 0xD11E ^ (case << 8)).unwrap();
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5),
                BoardInstance::synthetic(1, "kws", 250.0, 50.0, 1.8),
            ],
        };
        let cfg = FleetConfig {
            queue_cap: 1024,
            chaos: Some(spec),
            health: Some(HealthConfig {
                interval: std::time::Duration::from_millis(1),
                max_consecutive_failures: 2,
                ..Default::default()
            }),
            retry_budget: 50,
            // Low threshold so drift-corrected estimates actually cross
            // it once a slowdown clause lands.
            hedge_p99: 0.5,
            breaker: Some(BreakerConfig::default()),
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let n = 80;
        let mut pending = Vec::new();
        let (mut refused, mut shed) = (0usize, 0usize);
        for i in 0..n {
            // A third of the stream has no deadline, a third a tight
            // one (expiry and unmeetable-at-submit both reachable under
            // stalls and backlog), a third a generous one.
            let d_us = [0u64, 500, 1_000_000][rng.next_below(3) as usize];
            let tag = RequestTag::default().with_deadline_us(d_us);
            // Distinct inputs per request: this exercises hedging's
            // standalone flights, not input coalescing.
            let mut x = vec![0.1f32; tinyml_codesign::data::feature_dim("kws")];
            x[0] = i as f32;
            match handle.submit_tagged("kws", x, tag) {
                Ok(rx) => pending.push((d_us, rx)),
                Err(RouteError::DeadlineUnmeetable) => {
                    assert!(
                        d_us > 0,
                        "case {case} ({spec:?}): refused a deadline-free request"
                    );
                    refused += 1;
                }
                // Both breakers can be open in the same instant — the
                // whole fleet is masked and submit sheds.
                Err(RouteError::Overloaded) => shed += 1,
                Err(e) => panic!("case {case} ({spec:?}): rejected: {e:?}"),
            }
        }
        let (mut ok, mut exhausted, mut expired) = (0usize, 0usize, 0usize);
        for (d_us, rx) in &pending {
            match rx.recv_timeout(std::time::Duration::from_secs(10)) {
                Ok(Ok(_)) => ok += 1,
                Ok(Err(FleetError::Exhausted { attempts })) => {
                    assert!(attempts > 0, "case {case}: exhausted with 0 attempts");
                    exhausted += 1;
                }
                Ok(Err(FleetError::DeadlineExceeded)) => {
                    assert!(
                        *d_us > 0,
                        "case {case} ({spec:?}): a deadline-free request expired"
                    );
                    expired += 1;
                }
                Ok(Err(e)) => {
                    panic!("case {case} ({spec:?}): unexpected typed error {e:?}")
                }
                Err(e) => panic!(
                    "case {case} ({spec:?}): request hung or was dropped: {e:?}"
                ),
            }
            // Exactly one outcome: the reply channel must be spent.
            assert!(
                rx.try_recv().is_err(),
                "case {case} ({spec:?}): duplicate outcome on one request"
            );
        }
        assert_eq!(
            ok + exhausted + expired,
            pending.len(),
            "case {case} ({spec:?})"
        );
        assert_eq!(
            ok + exhausted + expired + refused + shed,
            n,
            "case {case} ({spec:?}): submit outcomes must cover the whole trace"
        );
        let summary = fleet.shutdown();
        assert_eq!(
            summary.snapshot.deadline.executed_expired, 0,
            "case {case} ({spec:?}): a board executed a request that was \
             already past its deadline"
        );
    }
}

/// Executor that emits a NaN with a distinctive payload in every output
/// row: the coalescing fan-out must hand followers a *bit-identical*
/// copy of the leader's output — NaN payload included — so a reply path
/// that recomputed, re-quantized, or round-tripped the value through
/// text would be caught here.
struct NanExecutor;

impl BatchExecutor for NanExecutor {
    fn device_batch(&mut self) -> tinyml_codesign::error::Result<usize> {
        Ok(8)
    }

    fn input_elems(&self) -> usize {
        4
    }

    fn num_outputs(&self) -> usize {
        2
    }

    fn execute(
        &mut self,
        x: &[f32],
        n: usize,
        out: &mut [f32],
    ) -> tinyml_codesign::error::Result<()> {
        for i in 0..n {
            out[2 * i] = f32::from_bits(0x7FC0_1234);
            out[2 * i + 1] = x[4 * i] * 3.0;
        }
        Ok(())
    }
}

#[test]
fn prop_coalesced_followers_get_bit_identical_replies_nan_included() {
    use std::sync::{mpsc, Arc, RwLock};
    use std::time::Instant;
    use tinyml_codesign::fleet::coalesce::Attach;
    use tinyml_codesign::fleet::Coalescer;

    let mut rng = SplitMix64::new(0xB17F_A40B);
    for case in 0..20u64 {
        let n_followers = 1 + rng.next_below(7) as usize;
        let co = Arc::new(Coalescer::new());
        let queue = Arc::new(BoardQueue::new(64));
        let peers: PeerList = Arc::new(RwLock::new(vec![queue.clone()]));
        let telemetry = Arc::new(Telemetry::new(1));

        // Leader + followers share one flight, registered before the
        // worker sees the request — exactly what submit_inner does.
        let (ltx, lrx) = mpsc::channel();
        let key = 0x5EED ^ (case << 4);
        let flight = match co.attach_or_lead(key, Priority::Standard, &ltx) {
            Attach::Lead(f) => f,
            _ => panic!("case {case}: first request must lead"),
        };
        let frxs: Vec<_> = (0..n_followers)
            .map(|i| {
                let (ftx, frx) = mpsc::channel();
                match co.attach_or_lead(key, Priority::Standard, &ftx) {
                    Attach::Follow => frx,
                    _ => panic!("case {case}: duplicate {i} must follow"),
                }
            })
            .collect();
        let x0 = rng.next_gaussian() as f32;
        let pushed = queue.try_push(FleetRequest {
            x: vec![x0; 4],
            reply: ltx,
            enqueued: Instant::now(),
            cache_key: None,
            tag: RequestTag::default(),
            trace: None,
            attempts: 0,
            failed_on: tinyml_codesign::fleet::queue::NOT_FAILED,
            flight: Some(flight),
            deadline: None,
            hedge: false,
        });
        assert!(pushed.is_ok(), "case {case}: leader rejected by empty queue");
        queue.close();

        let worker = {
            let queue = queue.clone();
            let peers = peers.clone();
            let co = co.clone();
            let sink = tinyml_codesign::fleet::TelemetrySink::resolve(&telemetry, 0);
            std::thread::spawn(move || {
                let inst = BoardInstance::synthetic(0, "mock", 10.0, 1.0, 1.0);
                let wcfg = WorkerConfig {
                    batch: BatchPolicy {
                        max_batch: 4,
                        max_wait: std::time::Duration::from_millis(1),
                    },
                    work_stealing: false,
                    pooled_replies: true,
                    trace: None,
                    retry: None,
                    retry_budget: 0,
                    health: None,
                    drift_time_scale: None,
                    deadline: Arc::new(DeadlineStats::default()),
                    hedge: None,
                    breaker: None,
                };
                run_worker(
                    &inst,
                    NanExecutor,
                    &queue,
                    &peers,
                    &wcfg,
                    &sink,
                    None,
                    Some(co.as_ref()),
                )
            })
        };
        assert_eq!(worker.join().unwrap(), 1, "case {case}: only the leader executes");

        let lead = lrx.recv().unwrap().unwrap();
        assert!(lead.output[0].is_nan(), "case {case}: executor must emit NaN");
        let lead_bits: Vec<u32> = lead.output.iter().map(|v| v.to_bits()).collect();
        for (i, frx) in frxs.iter().enumerate() {
            let fr = frx
                .recv()
                .expect("follower channel dropped")
                .expect("follower got an error from a healthy leader");
            let bits: Vec<u32> = fr.output.iter().map(|v| v.to_bits()).collect();
            assert_eq!(
                bits, lead_bits,
                "case {case}: follower {i} output not bit-identical to leader"
            );
            assert_eq!(fr.top1, lead.top1, "case {case}: follower {i} top1 differs");
            assert!(
                frx.try_recv().is_err(),
                "case {case}: follower {i} got a second outcome"
            );
        }
        let st = co.stats();
        assert_eq!(
            (st.leaders, st.followers),
            (1, n_followers as u64),
            "case {case}"
        );
        assert_eq!(
            (st.fanned_ok, st.fanned_err),
            (n_followers as u64, 0),
            "case {case}"
        );
    }
}

// ---------------------------------------------------------------------------
// Unified execution plane: trait conformance + elastic-fleet properties.
// ---------------------------------------------------------------------------

/// Shared conformance harness for every `BatchExecutor` implementation:
/// sane capacity/shapes, deterministic execute, per-sample independence
/// (the live prefix of a padded batch matches a solo run of the same
/// sample), and range-checked `n`.  Run against both the engine's
/// `ModelExecutor` and the fleet's `SimBoardExecutor` so the two serving
/// paths provably speak the same contract.
fn executor_conformance<E: BatchExecutor>(exec: &mut E, name: &str) {
    let batch = exec.device_batch().unwrap();
    let feat = exec.input_elems();
    let n_out = exec.num_outputs();
    assert!(batch >= 1 && feat >= 1 && n_out >= 1, "{name}: degenerate shapes");
    let mut rng = SplitMix64::new(0xC0F0_0001);
    let x: Vec<f32> =
        (0..batch * feat).map(|_| rng.next_gaussian() as f32).collect();
    let mut a = vec![0.0f32; batch * n_out];
    let mut b = vec![0.0f32; batch * n_out];
    exec.execute(&x, batch, &mut a).unwrap();
    exec.execute(&x, batch, &mut b).unwrap();
    assert_eq!(a, b, "{name}: execute must be deterministic");
    // Live-prefix independence: running only sample 0 must reproduce the
    // full batch's first-sample outputs bit for bit.
    let mut x1 = vec![0.0f32; batch * feat];
    x1[..feat].copy_from_slice(&x[..feat]);
    let mut one = vec![0.0f32; batch * n_out];
    exec.execute(&x1, 1, &mut one).unwrap();
    assert_eq!(&one[..n_out], &a[..n_out], "{name}: prefix diverges from solo run");
    // Out-of-range live counts are errors, not panics.
    assert!(exec.execute(&x, 0, &mut a).is_err(), "{name}: n=0 must fail");
    assert!(
        exec.execute(&x, batch + 1, &mut a).is_err(),
        "{name}: n>device_batch must fail"
    );
}

#[test]
fn executor_conformance_model_and_sim_board() {
    let rt = tinyml_codesign::runtime::Runtime::cpu().unwrap();
    let mut model = tinyml_codesign::runtime::LoadedModel::load(
        std::path::Path::new("/nonexistent"),
        "kws_mlp_w3a3",
    )
    .unwrap();
    let mut me = ModelExecutor { rt: &rt, model: &mut model };
    executor_conformance(&mut me, "ModelExecutor");
    for task in ["kws", "ic", "ad"] {
        let mut sb = SimBoardExecutor::for_task(task);
        executor_conformance(&mut sb, &format!("SimBoardExecutor/{task}"));
    }
}

/// Executor whose outputs are unmistakably its own: proves `run_worker`
/// has no inline inference path — every reply must have come through
/// `BatchExecutor::execute`.
struct MockExecutor {
    calls: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    batch: usize,
}

impl BatchExecutor for MockExecutor {
    fn device_batch(&mut self) -> tinyml_codesign::error::Result<usize> {
        Ok(self.batch)
    }

    fn input_elems(&self) -> usize {
        4
    }

    fn num_outputs(&self) -> usize {
        2
    }

    fn execute(
        &mut self,
        x: &[f32],
        n: usize,
        out: &mut [f32],
    ) -> tinyml_codesign::error::Result<()> {
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        for i in 0..n {
            out[2 * i] = x[4 * i] + 1.0;
            out[2 * i + 1] = 42.0;
        }
        Ok(())
    }
}

#[test]
fn run_worker_has_no_inline_inference_path() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{mpsc, Arc, RwLock};
    use std::time::Instant;

    let queue = Arc::new(BoardQueue::new(64));
    let peers: PeerList = Arc::new(RwLock::new(vec![queue.clone()]));
    let telemetry = Arc::new(Telemetry::new(1));
    let calls = Arc::new(AtomicUsize::new(0));
    let exec = MockExecutor { calls: calls.clone(), batch: 4 };
    let worker = {
        let queue = queue.clone();
        let sink = tinyml_codesign::fleet::TelemetrySink::resolve(&telemetry, 0);
        std::thread::spawn(move || {
            let inst = BoardInstance::synthetic(0, "mock", 10.0, 1.0, 1.0);
            let wcfg = WorkerConfig {
                batch: BatchPolicy {
                    max_batch: 4,
                    max_wait: std::time::Duration::from_millis(1),
                },
                work_stealing: true,
                pooled_replies: true,
                trace: None,
                retry: None,
                retry_budget: 0,
                health: None,
                drift_time_scale: None,
                deadline: Arc::new(DeadlineStats::default()),
                hedge: None,
                breaker: None,
            };
            run_worker(&inst, exec, &queue, &peers, &wcfg, &sink, None, None)
        })
    };
    let mut rxs = Vec::new();
    for i in 0..20 {
        let (tx, rx) = mpsc::channel();
        let req = FleetRequest {
            x: vec![i as f32; 4],
            reply: tx,
            enqueued: Instant::now(),
            cache_key: None,
            tag: RequestTag::default(),
            trace: None,
            attempts: 0,
            failed_on: tinyml_codesign::fleet::queue::NOT_FAILED,
            flight: None,
            deadline: None,
            hedge: false,
        };
        assert!(queue.try_push(req).is_ok(), "request {i} rejected");
        rxs.push((i, rx));
    }
    queue.close();
    let served = worker.join().unwrap();
    assert_eq!(served, 20);
    assert!(calls.load(Ordering::Relaxed) >= 1, "executor never invoked");
    for (i, rx) in rxs {
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(
            r.output,
            vec![i as f32 + 1.0, 42.0],
            "request {i}: output did not come from the mock executor"
        );
        assert_eq!(r.top1, 1);
        assert!(r.batch_size >= 1 && r.batch_size <= 4);
    }
}

#[test]
fn prop_scale_down_drains_every_request_exactly_once() {
    // Random interleavings of submits, scale-ups, and scale-downs:
    // every admitted request must come back exactly once — no drops
    // (drain-then-join) and no duplicates (each request is popped by
    // exactly one worker).
    let mut rng = SplitMix64::new(0x5CA1_E001);
    for case in 0..8 {
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 150.0, 30.0, 1.5),
                BoardInstance::synthetic(1, "kws", 150.0, 30.0, 1.5),
            ],
        };
        let cfg = FleetConfig {
            time_scale: 2.0,
            queue_cap: 512,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut pending = Vec::new();
        let mut submitted = 0u64;
        for _ in 0..40 {
            match rng.next_below(10) {
                0 => {
                    fleet.add_replica("kws").unwrap();
                }
                1 => {
                    // Retire a random slot; refusals (already retired /
                    // last replica) are part of the contract.
                    let n_slots = fleet.registry().len();
                    let id = rng.next_below(n_slots as u64) as usize;
                    let _ = fleet.retire_replica(id);
                }
                _ => {
                    for _ in 0..1 + rng.next_below(8) {
                        match handle.submit("kws", vec![0.1f32; 490]) {
                            Ok(rx) => {
                                pending.push(rx);
                                submitted += 1;
                            }
                            Err(RouteError::Overloaded) => {}
                            Err(e) => panic!("case {case}: unexpected {e:?}"),
                        }
                    }
                }
            }
        }
        for rx in &pending {
            rx.recv_timeout(std::time::Duration::from_secs(30))
                .expect("admitted request dropped by scaling")
                .expect("request must not fail without chaos");
            assert!(
                rx.try_recv().is_err(),
                "case {case}: duplicate reply for one request"
            );
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, submitted, "case {case}");
        assert_eq!(
            summary.served_per_worker.iter().sum::<u64>(),
            submitted,
            "case {case}"
        );
        assert!(
            summary.snapshot.scale_events.len()
                >= summary.served_per_worker.len().saturating_sub(2),
            "case {case}: every membership change must be recorded"
        );
    }
}

// ---------------------------------------------------------------------------
// Priority queue plane: conservation, shedding order, no starvation.
// ---------------------------------------------------------------------------

fn random_priority(rng: &mut SplitMix64) -> Priority {
    Priority::ALL[rng.next_below(3) as usize]
}

#[test]
fn prop_no_admitted_request_dropped_across_priority_classes() {
    // Random class/tenant mixes against a live fleet: every admitted
    // request comes back exactly once regardless of its class, and the
    // per-class served/shed accounting matches the caller's view.
    let mut rng = SplitMix64::new(0x9A10_0001);
    for case in 0..6 {
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 150.0, 30.0, 1.5),
                BoardInstance::synthetic(1, "kws", 400.0, 80.0, 1.8),
            ],
        };
        let cfg = FleetConfig {
            time_scale: 2.0,
            queue_cap: 32,
            work_stealing: case % 2 == 0,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut pending: Vec<(Priority, _)> = Vec::new();
        let mut admitted = [0u64; 3];
        let mut shed = [0u64; 3];
        for i in 0..150u32 {
            let p = random_priority(&mut rng);
            let tag = RequestTag::new(i % 5, p);
            match handle.submit_tagged("kws", vec![0.1f32; 490], tag) {
                Ok(rx) => {
                    admitted[p.idx()] += 1;
                    pending.push((p, rx));
                }
                Err(RouteError::Overloaded) => shed[p.idx()] += 1,
                Err(e) => panic!("case {case}: unexpected {e:?}"),
            }
        }
        for (p, rx) in &pending {
            rx.recv_timeout(std::time::Duration::from_secs(30))
                .unwrap_or_else(|_| panic!("case {case}: admitted {p} request dropped"))
                .expect("request must not fail without chaos");
            assert!(rx.try_recv().is_err(), "case {case}: duplicate reply");
        }
        let summary = fleet.shutdown();
        assert_eq!(summary.snapshot.served, admitted.iter().sum::<u64>(), "case {case}");
        for (i, c) in summary.snapshot.classes.iter().enumerate() {
            assert_eq!(c.served, admitted[i], "case {case} class {}", c.class);
            assert_eq!(c.shed, shed[i], "case {case} class {} sheds", c.class);
        }
    }
}

#[test]
fn priority_overload_sheds_batch_only() {
    // Synthetic overload: a single slow board buried under a Batch
    // burst.  Tiered admission must shed Batch (and only Batch) — the
    // Interactive/Standard load fits under their bounds by construction
    // (32 batch + 20 standard + 5 interactive <= 57 < queue_cap 64), so
    // any Interactive or Standard shed is an admission-ordering bug.
    let reg = Registry {
        instances: vec![BoardInstance::synthetic(0, "kws", 2000.0, 400.0, 1.5)],
    };
    let cfg = FleetConfig {
        queue_cap: 64,
        time_scale: 20.0,
        work_stealing: false,
        ..Default::default()
    };
    let fleet = Fleet::start(reg, cfg).unwrap();
    let handle = fleet.handle();
    let mut pending = Vec::new();
    let mut submit = |p: Priority, n: usize| {
        for _ in 0..n {
            if let Ok(rx) =
                handle.submit_tagged("kws", vec![0.1f32; 490], RequestTag::new(0, p))
            {
                pending.push(rx);
            }
        }
    };
    // Batch floods first; the urgent classes trickle in behind it.
    submit(Priority::Batch, 60);
    submit(Priority::Standard, 10);
    submit(Priority::Batch, 40);
    submit(Priority::Standard, 10);
    submit(Priority::Interactive, 5);
    for rx in &pending {
        rx.recv_timeout(std::time::Duration::from_secs(60))
            .expect("admitted request dropped")
            .expect("request must not fail without chaos");
    }
    let summary = fleet.shutdown();
    let classes = &summary.snapshot.classes;
    assert_eq!(classes[0].shed, 0, "interactive must never shed here");
    assert_eq!(classes[1].shed, 0, "standard fits under its bound");
    assert!(classes[2].shed > 0, "the batch flood must be shed");
    assert_eq!(
        summary.snapshot.served as usize + classes[2].shed as usize,
        125,
        "admitted + shed must cover the whole trace"
    );
}

#[test]
fn prop_no_class_starves_under_sustained_interactive_load() {
    // Random lower-class backlogs under a saturating interactive stream
    // (one fresh interactive arrival per pickup, forever): the
    // anti-starvation guard must drain every Standard and Batch request
    // within the guard's bound, while interactive keeps absolute
    // priority the rest of the time.
    let mut rng = SplitMix64::new(0x57A6_0001);
    for case in 0..40 {
        let n_std = 1 + rng.next_below(30) as usize;
        let n_batch = 1 + rng.next_below(30) as usize;
        let q = BoardQueue::new(8192);
        let mk = |p: Priority| {
            let (tx, _rx) = std::sync::mpsc::channel();
            FleetRequest {
                x: vec![0.0],
                reply: tx,
                enqueued: std::time::Instant::now(),
                cache_key: None,
                tag: RequestTag::new(0, p),
                trace: None,
                attempts: 0,
                failed_on: tinyml_codesign::fleet::queue::NOT_FAILED,
                flight: None,
                deadline: None,
                hedge: false,
            }
        };
        // Random interleave of the lower-class preload.
        let mut preload: Vec<Priority> = std::iter::repeat(Priority::Standard)
            .take(n_std)
            .chain(std::iter::repeat(Priority::Batch).take(n_batch))
            .collect();
        for i in (1..preload.len()).rev() {
            preload.swap(i, rng.next_below(i as u64 + 1) as usize);
        }
        for p in preload {
            q.try_push(mk(p)).unwrap();
        }
        let lower_total = n_std + n_batch;
        let mut lower_served = 0;
        let mut pops = 0usize;
        // Guard bound: at most INTERACTIVE_BURST+1 pops per lower-class
        // completion.
        let bound = lower_total
            * (tinyml_codesign::fleet::queue::INTERACTIVE_BURST as usize + 1)
            + 1;
        while lower_served < lower_total {
            q.try_push(mk(Priority::Interactive)).unwrap();
            let r = q.try_steal().expect("queue non-empty");
            pops += 1;
            if r.tag.priority != Priority::Interactive {
                lower_served += 1;
            }
            assert!(
                pops <= bound,
                "case {case}: lower classes starving ({lower_served}/{lower_total} \
                 after {pops} pops, n_std={n_std} n_batch={n_batch})"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Pooled reply path + sharded telemetry (the zero-allocation hot path).
// ---------------------------------------------------------------------------

#[test]
fn prop_pooled_replies_bit_identical_and_recycled_buffers_never_leak() {
    // Random take/fill/drop interleavings: every pooled copy is
    // bit-identical to the source slice, recycled buffers are reused
    // (the pool actually pools), and no recycled buffer ever exposes a
    // previous request's data — the pool poison-fills on return, and a
    // poison bit pattern showing through a take means the overwrite
    // was not total.
    let mut rng = SplitMix64::new(0x900C_0001);
    for case in 0..200 {
        let pool = ReplyPool::new(1 + rng.next_below(24) as usize);
        let mut live: Vec<(PooledVec, Vec<f32>)> = Vec::new();
        for step in 0..60 {
            if rng.next_f64() < 0.6 || live.is_empty() {
                let n = rng.next_below(40) as usize;
                let data: Vec<f32> = (0..n)
                    .map(|_| {
                        // Arbitrary bit patterns except the poison
                        // sentinel itself (kept distinguishable).
                        let mut b = rng.next_u64() as u32;
                        if b == POISON_BITS {
                            b ^= 1;
                        }
                        f32::from_bits(b)
                    })
                    .collect();
                let v = pool.take_copy(&data);
                assert_eq!(v.len(), data.len(), "case {case} step {step}: length");
                for (i, (a, b)) in v.iter().zip(&data).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "case {case} step {step} elem {i}: pooled copy diverged"
                    );
                }
                assert!(
                    v.iter().all(|x| x.to_bits() != POISON_BITS),
                    "case {case} step {step}: poison leaked through a take"
                );
                live.push((v, data));
            } else {
                // Drop a random live buffer back into the pool; the
                // survivors must be untouched by the recycling.
                let i = rng.next_below(live.len() as u64) as usize;
                live.swap_remove(i);
                for (j, (v, want)) in live.iter().enumerate() {
                    // Bit-level compare: the random payloads include
                    // NaNs, where `==` would lie.
                    assert!(
                        v.len() == want.len()
                            && v.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits()),
                        "case {case} step {step}: drop corrupted live buffer {j}"
                    );
                }
            }
        }
        drop(live);
        assert!(
            pool.recycled() > 0,
            "case {case}: pool never recycled a buffer — the zero-allocation \
             path is vacuous"
        );
    }
}

#[test]
fn prop_fleet_replies_identical_with_and_without_the_sharded_hotpath() {
    // The same deterministic trace through the sharded/pooled plane and
    // the global-lock/allocating control: outputs bit-identical request
    // for request (the surrogate executors are deterministic, so any
    // divergence is a pooling or cache-striping bug), accounting equal.
    let run = |global_hotpath: bool| {
        let reg = Registry {
            instances: vec![
                BoardInstance::synthetic(0, "kws", 80.0, 10.0, 1.5),
                BoardInstance::synthetic(1, "ad", 40.0, 5.0, 1.5),
            ],
        };
        let cfg = FleetConfig {
            cache_cap: 64,
            work_stealing: false,
            global_hotpath,
            ..Default::default()
        };
        let fleet = Fleet::start(reg, cfg).unwrap();
        let handle = fleet.handle();
        let mut rng = SplitMix64::new(0x1DE7_0001);
        // A small pool of inputs per task so repeats occur and the
        // cache path (hits through pooled buffers) is exercised too.
        let inputs: Vec<(&str, Vec<Vec<f32>>)> = ["kws", "ad"]
            .into_iter()
            .map(|task| {
                let dim = tinyml_codesign::data::feature_dim(task);
                let pool: Vec<Vec<f32>> = (0..4)
                    .map(|_| {
                        (0..dim).map(|_| rng.next_below(64) as f32 / 16.0).collect()
                    })
                    .collect();
                (task, pool)
            })
            .collect();
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for i in 0..120u32 {
            let (task, pool) = &inputs[rng.next_below(2) as usize];
            let x = pool[rng.next_below(4) as usize].clone();
            let tag = RequestTag::new(i % 4, random_priority(&mut rng));
            let r = handle.infer_tagged(task, x, tag).unwrap();
            outs.push(r.output.to_vec());
        }
        let summary = fleet.shutdown();
        (outs, summary.snapshot.served, summary.snapshot.cache.hits)
    };
    let (a, served_a, hits_a) = run(false);
    let (b, served_b, hits_b) = run(true);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x, y, "request {i}: pooled reply diverged from unpooled");
    }
    assert_eq!(served_a, served_b, "served accounting diverged");
    assert_eq!(hits_a, hits_b, "cache-hit accounting diverged");
    assert!(hits_a > 0, "trace never hit the cache — property is vacuous");
}

#[test]
fn prop_sharded_telemetry_merge_matches_global_collector() {
    // Random board counts, trace lengths, and seeds through the shared
    // lossless-merge harness (`telemetry::assert_merge_equivalence` —
    // the same driver the telemetry unit test and bench part 3 run at
    // their own sizes): the sharded collector's merged snapshot must
    // reproduce the global-lock collector's per-class served/shed and
    // p50/p99 (and tenants) exactly while no reservoir saturates.
    let mut rng = SplitMix64::new(0x5AAD_0002);
    for _case in 0..20 {
        let boards = 1 + rng.next_below(6) as usize;
        let batches = 50 + rng.next_below(250) as usize;
        tinyml_codesign::fleet::telemetry::assert_merge_equivalence(
            boards,
            batches,
            rng.next_u64(),
        );
    }
}

// ---------------------------------------------------------------------------
// Lifecycle-tracing plane: stage-histogram merge + event-ring properties.
// ---------------------------------------------------------------------------

#[test]
fn prop_stage_histogram_shard_merge_is_bucket_exact() {
    // Random per-shard TraceSample streams: the merged per-class stage
    // histograms in `Telemetry::snapshot` must equal a single global
    // collector bucket for bucket (and sum for sum) — the lossless-merge
    // contract of `prop_sharded_telemetry_merge_matches_global_collector`
    // extended to the tracing plane.  Per-board stage sets and the drift
    // accumulators are replayed against per-shard replicas the same way.
    use tinyml_codesign::fleet::trace::{DriftSample, StageSet, TraceSample};
    let mut rng = SplitMix64::new(0x7ACE_0001);
    for case in 0..25 {
        let boards = 1 + rng.next_below(6) as usize;
        let reg = Registry {
            instances: (0..boards)
                .map(|id| BoardInstance::synthetic(id, "kws", 100.0, 10.0, 1.5))
                .collect(),
        };
        let t = Telemetry::new(boards);
        let mut global: Vec<StageSet> = (0..3).map(|_| StageSet::default()).collect();
        let mut local: Vec<StageSet> = (0..boards).map(|_| StageSet::default()).collect();
        let mut drift_batches = vec![0u64; boards];
        let mut drift_pred = vec![0f64; boards];
        let mut drift_obs = vec![0u128; boards];
        for _ in 0..200 {
            let id = rng.next_below(boards as u64) as usize;
            let n = 1 + rng.next_below(4) as usize;
            let samples: Vec<TraceSample> = (0..n)
                .map(|_| TraceSample {
                    class: random_priority(&mut rng),
                    queue_wait_us: rng.next_below(1 << 20),
                    window_wait_us: rng.next_below(1 << 12),
                    exec_us: rng.next_below(1 << 16),
                    reply_us: rng.next_below(1 << 8),
                })
                .collect();
            let drift = (rng.next_f64() < 0.7).then(|| DriftSample {
                pred_us: 10.0 + rng.next_f64() * 1000.0,
                obs_us: rng.next_below(1 << 16) as u128,
            });
            for s in &samples {
                let spans = [s.queue_wait_us, s.window_wait_us, s.exec_us, s.reply_us];
                for (st, &us) in spans.iter().enumerate() {
                    global[s.class.idx()][st].record(us);
                    local[id][st].record(us);
                }
            }
            if let Some(d) = drift {
                drift_batches[id] += 1;
                drift_pred[id] += d.pred_us;
                drift_obs[id] += d.obs_us;
            }
            t.record_trace(id, &samples, drift);
        }
        let snap = t.snapshot(&reg);
        for (c, want) in global.iter().enumerate() {
            match &snap.classes[c].stages {
                Some(got) => assert_eq!(
                    &got[..],
                    &want[..],
                    "case {case} class {c}: merged stage set diverged from the \
                     global collector"
                ),
                None => assert!(
                    want.iter().all(|h| h.is_empty()),
                    "case {case} class {c}: stages missing despite recorded samples"
                ),
            }
        }
        for (id, want) in local.iter().enumerate() {
            match &snap.per_board[id].stages {
                Some(got) => assert_eq!(
                    &got[..],
                    &want[..],
                    "case {case} board {id}: shard stage set diverged"
                ),
                None => assert!(
                    want.iter().all(|h| h.is_empty()),
                    "case {case} board {id}: stages missing despite samples"
                ),
            }
            match &snap.per_board[id].drift {
                Some(d) => {
                    assert_eq!(d.batches, drift_batches[id], "case {case} board {id}");
                    assert!(
                        (d.predicted_exec_us - drift_pred[id]).abs() < 1e-6
                            && (d.observed_exec_us - drift_obs[id] as f64).abs() < 1e-6,
                        "case {case} board {id}: drift sums diverged"
                    );
                }
                None => assert_eq!(
                    drift_batches[id], 0,
                    "case {case} board {id}: drift missing despite batches"
                ),
            }
        }
    }
}

#[test]
fn prop_event_ring_never_reorders_and_drops_only_above_capacity() {
    // Random pushes scattered over the fleet ring and the board rings of
    // one `EventLog`: sequence numbers come back strictly increasing in
    // push order and in `dump_sorted`, nothing is dropped while the load
    // fits under per-ring capacity, and every drop above capacity is
    // counted (retained + dropped == pushed, always).
    use tinyml_codesign::fleet::trace::{EventLog, FleetEvent, ShedReason};
    let mut rng = SplitMix64::new(0x51E6_0001);
    for case in 0..60 {
        let cap = 1 + rng.next_below(64) as usize;
        let n_rings = 1 + rng.next_below(4) as usize;
        let log = EventLog::with_capacity(n_rings, cap);
        let n_events = rng.next_below(3 * cap as u64 + 4) as usize;
        let mut pushed: Vec<u64> = Vec::new();
        for i in 0..n_events {
            let ev = match rng.next_below(3) {
                0 => FleetEvent::Shed {
                    class: random_priority(&mut rng),
                    reason: ShedReason::ALL[rng.next_below(3) as usize],
                },
                1 => FleetEvent::Steal { thief: i, stolen: 1 + rng.next_below(4) },
                _ => FleetEvent::CacheInsertDenied {
                    task: "kws".into(),
                    class: random_priority(&mut rng),
                },
            };
            let seq = if rng.next_f64() < 0.25 {
                log.record_fleet(ev)
            } else {
                log.ring(rng.next_below(n_rings as u64) as usize).push(ev)
            };
            pushed.push(seq);
        }
        assert!(
            pushed.windows(2).all(|w| w[0] < w[1]),
            "case {case}: sequence numbers not allocated in push order"
        );
        let dump = log.dump_sorted();
        assert!(
            dump.windows(2).all(|w| w[0].seq < w[1].seq),
            "case {case}: dump_sorted reordered events"
        );
        let dropped = log.total_dropped() as usize;
        assert_eq!(
            dump.len() + dropped,
            n_events,
            "case {case}: events lost without being counted as dropped"
        );
        if n_events <= cap {
            // Under per-ring capacity no ring can overflow no matter how
            // the scatter fell, so retention must be verbatim.
            assert_eq!(dropped, 0, "case {case}: dropped below capacity");
            let seqs: Vec<u64> = dump.iter().map(|e| e.seq).collect();
            assert_eq!(seqs, pushed, "case {case}: retained events diverged");
        }
    }
    // Concurrent pushers into one ring: the sequence is allocated under
    // the ring lock, so the stored order must still be strictly
    // increasing and nothing drops when the total fits the capacity.
    let log = EventLog::with_capacity(1, 256);
    let ring = log.ring(0);
    std::thread::scope(|s| {
        for t in 0..4usize {
            let ring = ring.clone();
            s.spawn(move || {
                for i in 0u64..64 {
                    ring.push(tinyml_codesign::fleet::trace::FleetEvent::Steal {
                        thief: t,
                        stolen: i,
                    });
                }
            });
        }
    });
    let snap = ring.snapshot();
    assert_eq!(snap.len(), 256, "concurrent pushes under capacity must all land");
    assert!(
        snap.windows(2).all(|w| w[0].seq < w[1].seq),
        "concurrent pushes stored out of sequence order"
    );
    assert_eq!(log.total_dropped(), 0);
}

// ---------------------------------------------------------------------------
// Packed quantized kernel properties (the surrogate inference hot path).
// ---------------------------------------------------------------------------

/// The three task shapes the serving plane runs: KWS (12x490 MLP head),
/// IC (10x3072 over the flattened image), AD decoder (128x128).
const GEMM_SHAPES: [(&str, usize, usize); 3] =
    [("kws", 12, 490), ("ic", 10, 3072), ("ad", 128, 128)];

fn max_abs(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

/// f32 reference for the packed kernel: `dot(x, w) / dim` per row —
/// exactly `data::template_logits`, the code path the kernel replaced.
fn reference_logits(x: &[f32], rows: &[Vec<f32>]) -> Vec<f32> {
    tinyml_codesign::data::template_logits(x, rows)
}

#[test]
fn prop_packed_gemm_matches_f32_reference_within_quant_tolerance() {
    let mut rng = SplitMix64::new(0x6E33_0001);
    for (name, n_rows, cols) in GEMM_SHAPES {
        let rows: Vec<Vec<f32>> = (0..n_rows)
            .map(|_| (0..cols).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let packed = PackedLinear::pack(&rows, 1.0 / cols as f32);
        let mut scratch = ScratchArena::new();
        let mut out = vec![0.0f32; n_rows];
        for case in 0..30 {
            let x: Vec<f32> = (0..cols).map(|_| rng.next_gaussian() as f32).collect();
            packed.gemv(&x, &mut out, &mut scratch);
            let want = reference_logits(&x, &rows);
            let x_max = max_abs(&x);
            for (r, (&got, &ref_v)) in out.iter().zip(&want).enumerate() {
                let tol = quantized_max_abs_error(
                    x_max,
                    max_abs(&rows[r]),
                    cols,
                    1.0 / cols as f32,
                ) + 1e-5;
                assert!(
                    (got - ref_v).abs() <= tol,
                    "{name} case {case} row {r}: packed {got} vs f32 {ref_v} (tol {tol})"
                );
            }
        }
    }
}

#[test]
fn prop_packed_gemm_preserves_argmax_on_task_samples() {
    // Realistic inputs (the actual synthetic test sets) against the
    // actual class templates: wherever the f32 top-2 margin exceeds
    // twice the worst-case quantization error, the packed argmax must
    // match.  The margin gate keeps the property sound (quantization
    // may legitimately flip a near-tie); the coverage assert keeps it
    // from being vacuous.
    for (task, n_out) in [("kws", 12usize), ("ic", 10usize)] {
        let rows = tinyml_codesign::data::class_templates_f32(task, n_out);
        let cols = rows[0].len();
        let packed = PackedLinear::pack(&rows, 1.0 / cols as f32);
        let mut scratch = ScratchArena::new();
        let mut out = vec![0.0f32; n_out];
        let ts = tinyml_codesign::data::test_set(task, 80, 0x6E33_0002);
        let w_max_global = rows.iter().map(|r| max_abs(r)).fold(0.0f32, f32::max);
        let (mut gated, mut total) = (0usize, 0usize);
        for (i, s) in ts.samples.iter().enumerate() {
            let want = reference_logits(&s.x, &rows);
            packed.gemv(&s.x, &mut out, &mut scratch);
            let tol = quantized_max_abs_error(
                max_abs(&s.x),
                w_max_global,
                cols,
                1.0 / cols as f32,
            );
            let top1 = tinyml_codesign::runtime::argmax(&want);
            let margin = want[top1]
                - want
                    .iter()
                    .enumerate()
                    .filter(|&(c, _)| c != top1)
                    .map(|(_, &v)| v)
                    .fold(f32::NEG_INFINITY, f32::max);
            total += 1;
            if margin > 2.0 * tol {
                gated += 1;
                assert_eq!(
                    tinyml_codesign::runtime::argmax(&out),
                    top1,
                    "{task} sample {i}: argmax flipped despite margin {margin} > 2*tol {tol}"
                );
            }
        }
        assert!(
            gated * 3 >= total,
            "{task}: margin gate passed only {gated}/{total} samples — property is vacuous"
        );
    }
}

#[test]
fn prop_packed_gemm_batched_bit_identical_to_single() {
    // Integer accumulation is exact, so tiling over the batch cannot
    // change a single bit relative to the per-sample path.
    let mut rng = SplitMix64::new(0x6E33_0003);
    for case in 0..25 {
        let n_rows = 1 + rng.next_below(24) as usize;
        let cols = 1 + rng.next_below(300) as usize;
        let n = 1 + rng.next_below(12) as usize;
        let rows: Vec<Vec<f32>> = (0..n_rows)
            .map(|_| (0..cols).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let packed = PackedLinear::pack(&rows, 1.0 / cols as f32);
        let mut scratch = ScratchArena::new();
        let x: Vec<f32> = (0..n * cols).map(|_| rng.next_gaussian() as f32).collect();
        let mut batched = vec![0.0f32; n * n_rows];
        packed.gemm_batch(&x, &mut batched, &mut scratch);
        let mut single = vec![0.0f32; n_rows];
        for s in 0..n {
            packed.gemv(&x[s * cols..(s + 1) * cols], &mut single, &mut scratch);
            assert_eq!(
                &batched[s * n_rows..(s + 1) * n_rows],
                &single[..],
                "case {case} sample {s}: batched path diverged from single"
            );
        }
    }
}

#[test]
fn prop_simd_dot_bit_identical_to_scalar_on_every_level() {
    // Integer accumulation is associative, so every compiled-in SIMD
    // dot (AVX2 / SSE2 / NEON — whatever this CPU supports) must equal
    // the scalar oracle EXACTLY, bit for bit, on arbitrary i8 data
    // (including -128, outside the |q| <= 127 range the quantizer
    // emits) and on every ragged tail around the 16-lane width.
    let mut rng = SplitMix64::new(0x51D_0001);
    let levels = simd::available_levels();
    assert!(levels.contains(&simd::SimdLevel::Scalar));
    for case in 0..60 {
        // Cover sub-lane, lane-aligned, lane+tail, and long lengths.
        let len = match case % 6 {
            0 => rng.next_below(16) as usize,
            1 => 16 * (1 + rng.next_below(8) as usize),
            2 => 16 * (1 + rng.next_below(8) as usize) + 1 + rng.next_below(15) as usize,
            3 => 1 + rng.next_below(600) as usize,
            4 => 3072,
            _ => 490,
        };
        let a: Vec<i8> = (0..len).map(|_| rng.next_below(256) as u8 as i8).collect();
        let b: Vec<i8> = (0..len).map(|_| rng.next_below(256) as u8 as i8).collect();
        let want = simd::dot_i8_scalar(&a, &b);
        for &level in &levels {
            let got = simd::dot_i8_for(level).expect("listed level must resolve")(&a, &b);
            assert_eq!(
                got,
                want,
                "case {case}: level {} diverged from scalar at len {len}",
                level.name()
            );
        }
    }
}

#[test]
fn prop_simd_gemm_batch_bit_identical_to_scalar_oracle() {
    // The dispatched gemm_batch (whatever level this CPU selected) vs
    // the scalar-oracle path with identical blocking: outputs must be
    // bit-identical on random shapes — ragged columns (cols % 16 != 0),
    // tiny and empty row sets, batches of 0..6 samples, column counts
    // crossing the L1 block boundary, and samples poisoned with
    // NaN/Inf elements (both paths share the quantizer, which zeroes
    // any non-finite sample — pinned by unit test; here we pin that
    // the two paths stay identical under it).
    let mut rng = SplitMix64::new(0x51D_0002);
    let mut scratch = ScratchArena::new();
    for case in 0..40 {
        let n_rows = match case % 5 {
            0 => 0,
            1 => 1,
            _ => 1 + rng.next_below(24) as usize,
        };
        let cols = match case % 4 {
            0 => 1 + rng.next_below(15) as usize,        // sub-lane
            1 => 16 * (1 + rng.next_below(30) as usize), // lane-aligned
            2 => 2048 + 1 + rng.next_below(80) as usize, // crosses COL_BLOCK
            _ => 1 + rng.next_below(600) as usize,       // ragged
        };
        let n = rng.next_below(6) as usize;
        let rows: Vec<Vec<f32>> = (0..n_rows)
            .map(|_| (0..cols).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let packed = PackedLinear::pack(&rows, 1.0 / cols as f32);
        let mut x: Vec<f32> =
            (0..n * cols).map(|_| rng.next_gaussian() as f32).collect();
        // Poison ~1 in 4 samples with a non-finite element.
        for s in 0..n {
            if rng.next_below(4) == 0 && cols > 0 {
                let j = rng.next_below(cols as u64) as usize;
                x[s * cols + j] = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY][s % 3];
            }
        }
        let mut dispatched = vec![0.0f32; n * n_rows];
        let mut oracle = vec![0.0f32; n * n_rows];
        packed.gemm_batch(&x, &mut dispatched, &mut scratch);
        packed.gemm_batch_scalar(&x, &mut oracle, &mut scratch);
        let (d_bits, o_bits): (Vec<u32>, Vec<u32>) = (
            dispatched.iter().map(|v| v.to_bits()).collect(),
            oracle.iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(
            d_bits, o_bits,
            "case {case}: {} gemm (rows={n_rows} cols={cols} n={n}) diverged \
             bitwise from the scalar oracle",
            simd::active_level().name()
        );
    }
}

#[test]
fn prop_simd_force_scalar_dispatch() {
    // The kill-switch policy is pure and absolute: forcing scalar wins
    // over any detected feature set...
    assert_eq!(simd::select_level(true), simd::SimdLevel::Scalar);
    // ...an unforced selection always resolves to a runnable path...
    assert!(simd::dot_i8_for(simd::select_level(false)).is_some());
    // ...and when the whole process runs under TINYML_FORCE_SCALAR=1
    // (the ci.sh scalar-oracle rerun does exactly that), the live
    // dispatch table must have honored it.
    if simd::force_scalar_from_env() {
        assert_eq!(
            simd::active_level(),
            simd::SimdLevel::Scalar,
            "TINYML_FORCE_SCALAR=1 was set at startup but the dispatch \
             table selected a SIMD path"
        );
    }
}

#[test]
fn prop_prefix_sum_smoothing_equals_naive_exactly() {
    // Inputs on the 2^-8 dyadic grid with |v| <= 4: every window sum is
    // exact in f32 and every prefix sum is exact in f64, so the O(n)
    // prefix-sum kernel must agree with the O(n*window) naive moving
    // average bit-for-bit.
    let mut rng = SplitMix64::new(0x6E33_0004);
    let mut scratch = ScratchArena::new();
    for case in 0..120 {
        let n = 1 + rng.next_below(300) as usize;
        let window = [1usize, 3, 5, 9, 15][rng.next_below(5) as usize];
        let x: Vec<f32> = (0..n)
            .map(|_| (rng.next_below(2049) as i64 - 1024) as f32 / 256.0)
            .collect();
        let naive = tinyml_codesign::data::moving_average_f32(&x, window);
        let mut fast = vec![0.0f32; n];
        SmoothKernel::new(window).smooth_into(&x, &mut fast, &mut scratch);
        assert_eq!(fast, naive, "case {case}: n={n} window={window}");
    }
}

#[test]
fn prop_prefix_sum_smoothing_close_on_arbitrary_inputs() {
    // Off the grid the two differ only by f32-vs-f64 accumulation order;
    // bound it tightly on gaussian data (the AD spectral frames).
    let mut rng = SplitMix64::new(0x6E33_0005);
    let mut scratch = ScratchArena::new();
    for case in 0..60 {
        let n = 1 + rng.next_below(256) as usize;
        let x: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
        let naive = tinyml_codesign::data::moving_average_f32(&x, 9);
        let mut fast = vec![0.0f32; n];
        SmoothKernel::new(9).smooth_into(&x, &mut fast, &mut scratch);
        for (i, (&f, &w)) in fast.iter().zip(&naive).enumerate() {
            assert!((f - w).abs() < 1e-4, "case {case} i={i}: {f} vs {w}");
        }
    }
}
