"""Pallas kernels vs pure-jnp oracles — the CORE L1 correctness signal.

Hypothesis sweeps shapes (including non-tile-aligned, degenerate, and
single-row cases) and values; every kernel must match its oracle to f32
round-off over the whole space.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binary_gemm, matmul, multithreshold
from compile.kernels.binary_gemm import binary_gemm_ste
from compile.kernels.qmatmul import matmul_untiled
from compile.kernels import ref

dims = st.integers(min_value=1, max_value=40)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_oracle(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    got = matmul(jnp.array(x), jnp.array(w))
    want = ref.matmul_ref(jnp.array(x), jnp.array(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    m=dims, k=dims, n=dims,
    bm=st.sampled_from([1, 3, 8, 16]),
    bn=st.sampled_from([1, 4, 8, 128]),
    bk=st.sampled_from([1, 5, 8, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_block_shape_invariance(m, k, n, bm, bn, bk, seed):
    """The result must not depend on the tiling (the FPGA reuse factor)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    got = matmul_untiled(jnp.array(x), jnp.array(w), bm=bm, bn=bn, bk=bk)
    want = ref.matmul_ref(jnp.array(x), jnp.array(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_binary_gemm_matches_xnor_popcount_oracle(m, k, n, seed):
    rng = np.random.default_rng(seed)
    xb = np.sign(rng.standard_normal((m, k))).astype(np.float32)
    wb = np.sign(rng.standard_normal((k, n))).astype(np.float32)
    xb[xb == 0] = 1.0
    wb[wb == 0] = 1.0
    got = binary_gemm(jnp.array(xb), jnp.array(wb))
    want = ref.binary_gemm_ref(jnp.array(xb), jnp.array(wb))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_binary_gemm_equals_float_product(m, k, n, seed):
    """dot(a, b) == K - 2*popcount(xor) — the FINN LUT-datapath identity."""
    rng = np.random.default_rng(seed)
    xb = np.where(rng.standard_normal((m, k)) >= 0, 1.0, -1.0).astype(np.float32)
    wb = np.where(rng.standard_normal((k, n)) >= 0, 1.0, -1.0).astype(np.float32)
    got = binary_gemm(jnp.array(xb), jnp.array(wb))
    np.testing.assert_allclose(np.asarray(got), xb @ wb, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=dims,
    c=st.integers(1, 24),
    t=st.integers(1, 15),
    seed=st.integers(0, 2**31 - 1),
)
def test_multithreshold_matches_oracle(b, c, t, seed):
    rng = np.random.default_rng(seed)
    x = (4.0 * rng.standard_normal((b, c))).astype(np.float32)
    th = np.sort(rng.standard_normal((c, t)), axis=1).astype(np.float32)
    got = multithreshold(jnp.array(x), jnp.array(th))
    want = ref.multithreshold_ref(jnp.array(x), jnp.array(th))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_multithreshold_monotone_in_input():
    x = jnp.linspace(-3, 3, 61)[:, None] * jnp.ones((1, 4))
    th = jnp.tile(jnp.linspace(-1, 1, 7)[None, :], (4, 1))
    out = np.asarray(multithreshold(x, th))
    assert (np.diff(out, axis=0) >= 0).all()


def test_matmul_gradients_flow_through_pallas():
    """custom_vjp wiring: grads equal the analytic GEMM gradients."""
    import jax

    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((5, 7)).astype(np.float32))
    w = jnp.array(rng.standard_normal((7, 3)).astype(np.float32))

    def f(x, w):
        return jnp.sum(matmul(x, w) ** 2)

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    y = np.asarray(x) @ np.asarray(w)
    np.testing.assert_allclose(np.asarray(gx), 2 * y @ np.asarray(w).T, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(x).T @ (2 * y), rtol=1e-4)


def test_binary_gemm_ste_gradients():
    import jax

    rng = np.random.default_rng(1)
    xb = jnp.array(np.where(rng.standard_normal((4, 6)) >= 0, 1.0, -1.0).astype(np.float32))
    wb = jnp.array(np.where(rng.standard_normal((6, 3)) >= 0, 1.0, -1.0).astype(np.float32))

    def f(x, w):
        return jnp.sum(binary_gemm_ste(x, w))

    gx, gw = jax.grad(f, argnums=(0, 1))(xb, wb)
    ones = np.ones((4, 3), np.float32)
    np.testing.assert_allclose(np.asarray(gx), ones @ np.asarray(wb).T, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(xb).T @ ones, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (1, 513, 1), (257, 1, 3), (8, 128, 128)])
def test_matmul_edge_shapes(m, k, n):
    rng = np.random.default_rng(7)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    got = matmul(jnp.array(x), jnp.array(w))
    np.testing.assert_allclose(np.asarray(got), x @ w, rtol=2e-5, atol=2e-5)
