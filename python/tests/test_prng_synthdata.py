"""Cross-language PRNG vectors + synthetic dataset sanity.

The splitmix64 test vectors here are duplicated verbatim in
``rust/src/data/prng.rs`` — if either side drifts, templates diverge and
Rust-side evaluation silently measures a different task.
"""

import math

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import synthdata
from compile.prng import MASK64, SplitMix64, class_template, template_seed

# Reference vectors for seed 0x DEADBEEF (first 4 outputs) — asserted
# identically in rust/src/data/prng.rs::tests::splitmix_vectors.
SPLITMIX_SEED = 0xDEADBEEF
SPLITMIX_EXPECT = [
    0x4ADFB90F68C9EB9B,
    0xDE586A3141A10922,
    0x021FBC2F8E1CFC1D,
    0x7466CE737BE16790,
]


def test_splitmix64_reference_vectors():
    rng = SplitMix64(SPLITMIX_SEED)
    got = [rng.next_u64() for _ in range(4)]
    assert got == SPLITMIX_EXPECT, [hex(g) for g in got]


def test_f64_in_unit_interval():
    rng = SplitMix64(12345)
    vals = [rng.next_f64() for _ in range(1000)]
    assert all(0.0 <= v < 1.0 for v in vals)
    assert 0.4 < np.mean(vals) < 0.6


def test_gaussian_moments():
    rng = SplitMix64(99)
    vals = rng.gaussian_vec(4000)
    assert abs(vals.mean()) < 0.08
    assert abs(vals.std() - 1.0) < 0.08


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, MASK64), cls=st.integers(0, 200))
def test_template_deterministic(seed, cls):
    a = class_template(seed, cls, 32)
    b = class_template(seed, cls, 32)
    np.testing.assert_array_equal(a, b)


def test_templates_distinct_across_classes():
    t = [synthdata.ic_template(c) for c in range(10)]
    for i in range(10):
        for j in range(i + 1, 10):
            assert np.abs(t[i] - t[j]).max() > 0.5


def test_ic_batch_ranges():
    rng = np.random.default_rng(0)
    x, y = synthdata.ic_batch(rng, 32)
    assert x.shape == (32, 32, 32, 3)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)) <= set(range(10))


def test_kws_batch_shapes_and_silence():
    rng = np.random.default_rng(0)
    x, y = synthdata.kws_batch(rng, 200)
    assert x.shape == (200, 490)
    sil = x[y == synthdata.KWS_SILENCE]
    spoken = x[y < 10]
    assert sil.std() < 0.3 * spoken.std()  # silence really is quieter


def test_ad_anomalies_have_higher_energy_deviation():
    rng = np.random.default_rng(0)
    xn, _ = synthdata.ad_batch(rng, 200, anomalous=False)
    xa, _ = synthdata.ad_batch(rng, 200, anomalous=True)
    prof = synthdata.ad_profile(0)
    dn = np.abs(xn - prof).max(axis=1).mean()
    da = np.abs(xa - prof).max(axis=1).mean()
    assert da > dn * 1.3, (dn, da)


def test_ad_profile_is_smooth():
    prof = synthdata.ad_profile(0)
    raw = class_template(synthdata.AD_SEED, 0, synthdata.AD_DIM)
    assert np.abs(np.diff(prof)).mean() < 0.5 * np.abs(np.diff(raw)).mean()


def test_linear_separability_gap():
    """Nearest-template classification must beat chance by a wide margin —
    the task is learnable — but not be perfect — quantization must bite."""
    rng = np.random.default_rng(7)
    x, y = synthdata.kws_batch(rng, 400)
    temps = np.stack([synthdata.kws_template(c) for c in range(10)])
    keyword_mask = y < 10
    xs, ys = x[keyword_mask], y[keyword_mask]
    d = ((xs[:, None, :] - temps[None, :, :]) ** 2).sum(-1)
    acc = (d.argmin(1) == ys).mean()
    assert 0.7 < acc <= 1.0, acc
