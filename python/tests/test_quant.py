"""Quantizer and BN-folding properties (the §3.3.1 math)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quant
from compile.kernels import multithreshold


@settings(max_examples=30, deadline=None)
@given(bits=st.integers(2, 12), int_bits=st.integers(0, 4),
       seed=st.integers(0, 2**31 - 1))
def test_fixed_point_quant_grid(bits, int_bits, seed):
    """Outputs lie on the 2^-f grid, within range, idempotent."""
    if int_bits >= bits:
        return
    rng = np.random.default_rng(seed)
    x = jnp.array((8 * rng.standard_normal(64)).astype(np.float32))
    q = quant.fixed_point_quant(x, bits, int_bits)
    step = 2.0 ** -(bits - 1 - int_bits)
    grid = np.asarray(q) / step
    np.testing.assert_allclose(grid, np.round(grid), atol=1e-4)
    assert np.all(np.asarray(q) >= -(2.0 ** int_bits) - 1e-6)
    assert np.all(np.asarray(q) <= 2.0 ** int_bits - step + 1e-6)
    q2 = quant.fixed_point_quant(q, bits, int_bits)
    np.testing.assert_allclose(np.asarray(q2), np.asarray(q), atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(bits=st.integers(2, 8), seed=st.integers(0, 2**31 - 1))
def test_int_weight_quant_levels(bits, seed):
    rng = np.random.default_rng(seed)
    w = jnp.array(rng.standard_normal(128).astype(np.float32))
    q = np.asarray(quant.int_weight_quant(w, bits))
    # No more than 2^bits distinct levels.
    assert len(np.unique(np.round(q, 6))) <= 2**bits
    # Max-magnitude weight survives quantization (scale anchored to it).
    assert abs(q).max() > 0.9 * abs(np.asarray(w)).max()


def test_bipolar_quant_values_and_grad():
    x = jnp.array([-2.0, -0.3, 0.0, 0.4, 3.0])
    q = np.asarray(quant.bipolar_quant(x))
    np.testing.assert_array_equal(q, [-1.0, -1.0, 1.0, 1.0, 1.0])
    g = jax.grad(lambda v: jnp.sum(quant.bipolar_quant(v)))(x)
    # Hard-tanh STE: gradient 1 inside [-1,1], 0 outside.
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 1.0, 0.0])


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 16), c=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_fold_bn_exact_equivalence(n, c, seed):
    """x @ k_folded + b_folded == BN(x @ k + b) — eq. 3-4, corrected form."""
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.standard_normal((n, 8)).astype(np.float32))
    k = jnp.array(rng.standard_normal((8, c)).astype(np.float32))
    b = jnp.array(rng.standard_normal(c).astype(np.float32))
    gamma = jnp.array((1 + 0.5 * rng.standard_normal(c)).astype(np.float32))
    beta = jnp.array(rng.standard_normal(c).astype(np.float32))
    mean = jnp.array(rng.standard_normal(c).astype(np.float32))
    var = jnp.array((0.5 + rng.random(c)).astype(np.float32))
    eps = 1e-3
    kf, bf = quant.fold_bn(k, b, gamma, beta, mean, var, eps)
    folded = x @ kf + bf
    bn = gamma * ((x @ k + b) - mean) / jnp.sqrt(var + eps) + beta
    np.testing.assert_allclose(np.asarray(folded), np.asarray(bn), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(bits=st.integers(2, 6), seed=st.integers(0, 2**31 - 1))
def test_multithreshold_realizes_act_quant(bits, seed):
    """Streamlining correctness: MT node == uint_act_quant ∘ relu / step.

    This is the proof obligation behind FINN's streamlining pass (§3.5):
    the quantized activation and its threshold implementation agree on
    every input.
    """
    rng = np.random.default_rng(seed)
    c = 6
    x = jnp.array((3.0 * rng.standard_normal((9, c))).astype(np.float32))
    th_row = quant.act_thresholds(bits, act_range=4.0)
    th = jnp.tile(th_row[None, :], (c, 1))
    levels = multithreshold(x, th)
    step = 4.0 / (2**bits - 1)
    via_mt = step * np.asarray(levels)
    direct = np.asarray(quant.uint_act_quant(jax.nn.relu(x), bits, act_range=4.0))
    np.testing.assert_allclose(via_mt, direct, atol=1e-5)


def test_uint_act_quant_levels():
    x = jnp.linspace(-1, 6, 200)
    q = np.asarray(quant.uint_act_quant(x, 3, act_range=4.0))
    assert q.min() >= 0.0
    assert q.max() <= 4.0 + 1e-6
    assert len(np.unique(np.round(q, 5))) <= 8
