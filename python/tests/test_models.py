"""Model-level checks: shapes, param counts (Table 1), train-step sanity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import synthdata
from compile.models import MODELS, common, topology_only_variants


def _trainable_count(params):
    return sum(
        int(np.prod(v.shape))
        for k, v in params.items()
        if not (k.endswith(".mean") or k.endswith(".var"))
    )


@pytest.mark.parametrize("name", ["kws_mlp_w3a3", "ad_autoencoder"])
def test_apply_shapes(name):
    m = MODELS[name]
    p = m.init_params(0)
    rng = np.random.default_rng(0)
    x, _ = synthdata.batch_for(m.task, rng, 3)
    out, updates = m.apply(p, jnp.array(x), False)
    assert out.shape == (3, m.num_outputs)
    assert updates == {}


def test_kws_param_count_matches_table1():
    """490*256 + 256*256 + 256*256 + 256*12 == 259 584 exactly."""
    p = MODELS["kws_mlp_w3a3"].init_params(0)
    kernels = sum(
        int(np.prod(v.shape)) for k, v in p.items() if k.endswith(".kernel")
    )
    assert kernels == 259_584


def test_ic_hls4ml_param_count_near_table1():
    """Paper: 58 115; our reconstruction must land within 2%."""
    p = MODELS["ic_hls4ml"].init_params(0)
    n = _trainable_count(p)
    assert abs(n - 58_115) / 58_115 < 0.02, n


def test_ic_finn_full_topology_param_count():
    """The full-size CNV-W1A1 topology must count ~1.54 M params."""
    topo = [t for t in topology_only_variants() if t["name"] == "ic_finn_full"][0]
    dense_conv = sum(
        n["params"] for n in topo["nodes"] if n["op"] in ("Conv2D", "Dense")
    )
    # Umuroglu et al. CNV: 1 542 848 conv+fc weights.
    assert abs(dense_conv - 1_542_848) / 1_542_848 < 0.06, dense_conv


@pytest.mark.parametrize("name", ["kws_mlp_w3a3", "ad_autoencoder"])
def test_train_step_reduces_loss(name):
    """A handful of SGD steps on a fixed batch must reduce the loss."""
    m = MODELS[name]
    p = m.init_params(0)
    rng = np.random.default_rng(42)
    x, y = synthdata.batch_for(m.task, rng, 16)
    x, y = jnp.array(x), jnp.array(y)
    first = None
    for _ in range(5):
        p, loss = common.sgd_train_step(m.loss_and_updates, p, x, y, 0.05)
        if first is None:
            first = float(loss)
    assert float(loss) < first, (first, float(loss))


def test_bn_running_stats_updated_by_train_step():
    m = MODELS["kws_mlp_w3a3"]
    p = m.init_params(0)
    rng = np.random.default_rng(1)
    x, y = synthdata.batch_for(m.task, rng, 16)
    p2, _ = common.sgd_train_step(m.loss_and_updates, p, jnp.array(x), jnp.array(y), 0.05)
    moved = np.abs(np.asarray(p2["l01_bn.mean"]) - np.asarray(p["l01_bn.mean"])).max()
    assert moved > 0.0


def test_topologies_have_consistent_chains():
    for name, m in MODELS.items():
        topo = m.topology()
        assert topo["nodes"], name
        assert topo["total_params"] > 0, name
        ops = {n["op"] for n in topo["nodes"]}
        assert ops <= {
            "Conv2D", "Dense", "BatchNorm", "ReLU", "BipolarAct",
            "MaxPool", "Flatten", "Softmax",
        }, (name, ops)


def test_ad_loss_is_reconstruction():
    m = MODELS["ad_autoencoder"]
    p = m.init_params(0)
    rng = np.random.default_rng(3)
    x, y = synthdata.batch_for("ad", rng, 8)
    loss, _ = m.loss_and_updates(p, jnp.array(x), jnp.array(y))
    recon, _ = m.apply(p, jnp.array(x), True)
    want = float(jnp.mean((recon - jnp.array(x)) ** 2))
    assert abs(float(loss) - want) < 1e-5
