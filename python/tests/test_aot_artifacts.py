"""AOT artifact integrity: manifests, param files, HLO text headers.

Runs against ``artifacts/`` when present (after ``make artifacts``);
otherwise exports one small model to a tmpdir and checks that.
"""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _load_index():
    path = os.path.join(ART, "index.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_every_model_has_manifest_topology_and_hlo():
    idx = _load_index()
    for name in idx["models"]:
        for suffix in ("manifest.json", "topology.json"):
            assert os.path.exists(os.path.join(ART, f"{name}_{suffix}")), name
        with open(os.path.join(ART, f"{name}_manifest.json")) as f:
            man = json.load(f)
        for tag in ("fwd1", "fwd64", "train"):
            assert tag in man["artifacts"], (name, tag)
            hlo = os.path.join(ART, man["artifacts"][tag]["file"])
            assert os.path.exists(hlo), hlo
            with open(hlo) as fh:
                head = fh.read(200)
            assert "HloModule" in head, hlo


def test_param_files_match_manifest_shapes():
    idx = _load_index()
    for name in idx["models"]:
        with open(os.path.join(ART, f"{name}_manifest.json")) as f:
            man = json.load(f)
        for p in man["params"]:
            path = os.path.join(ART, p["file"])
            n = int(np.prod(p["shape"])) if p["shape"] else 1
            assert os.path.getsize(path) == 4 * n, (name, p["name"])


def test_param_order_is_sorted():
    """Rust relies on sorted-name flattening matching jax's dict order."""
    idx = _load_index()
    for name in idx["models"]:
        with open(os.path.join(ART, f"{name}_manifest.json")) as f:
            man = json.load(f)
        names = [p["name"] for p in man["params"]]
        assert names == sorted(names), name


def test_topology_only_variants_present():
    idx = _load_index()
    for name in idx["topology_only"]:
        path = os.path.join(ART, f"{name}_topology.json")
        assert os.path.exists(path)
        with open(path) as f:
            topo = json.load(f)
        assert topo["total_params"] > 0


def test_train_artifact_io_arity():
    """train HLO: inputs = P params + x + y + lr; outputs = P params + loss."""
    idx = _load_index()
    name = idx["models"][0]
    with open(os.path.join(ART, f"{name}_manifest.json")) as f:
        man = json.load(f)
    n_params = len(man["params"])
    hlo_path = os.path.join(ART, man["artifacts"]["train"]["file"])
    with open(hlo_path) as f:
        text = f.read()
    entry = text.split("ENTRY")[1]
    n_args = entry.split("->")[0].count("parameter")
    # HLO text may not literally say "parameter" per arg in the signature;
    # fall back to counting %Arg_ occurrences.
    n_args = text.count("%Arg_") // 2 or n_args  # declared + used at least once
    assert n_args >= n_params + 3 or text.count("Arg_") >= n_params + 3
