"""AOT artifact builder: JAX models -> HLO text + topology + params.

Python runs ONCE (`make artifacts`); the Rust binary is self-contained
afterwards.  For every model variant this emits into ``artifacts/``:

* ``<name>_fwd1.hlo.txt``   — batch-1 inference graph (the EEMBC path)
* ``<name>_fwdN.hlo.txt``   — batch-N inference graph (accuracy mode)
* ``<name>_train.hlo.txt``  — one SGD step: (params..., x, y, lr) ->
  (params'..., loss); Rust round-trips the parameter literals
* ``<name>_topology.json``  — the QONNX-like IR for the Rust compiler
* ``<name>_manifest.json``  — parameter order/shapes + artifact index
* ``params/<name>/NNN.bin`` — raw little-endian f32 initial parameters

Interchange is HLO **text**: the image's xla_extension 0.5.1 rejects
jax>=0.5 serialized HloModuleProto (64-bit instruction ids); the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .models import MODELS, topology_only_variants

TRAIN_BATCH_KEY = "train_batch"
EVAL_BATCH = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def flat_param_names(params: dict) -> list[str]:
    return sorted(params.keys())


def export_model(mdef, out_dir: str, skip_train: bool = False) -> dict:
    """Export one model variant; returns its manifest dict."""
    params = mdef.init_params(0)
    names = flat_param_names(params)
    pdir = os.path.join(out_dir, "params", mdef.name)
    os.makedirs(pdir, exist_ok=True)
    plist = []
    for i, n in enumerate(names):
        arr = np.asarray(params[n], dtype=np.float32)
        fname = f"{i:03d}.bin"
        arr.tofile(os.path.join(pdir, fname))
        plist.append({"name": n, "shape": list(arr.shape), "file": f"params/{mdef.name}/{fname}"})

    in_shape = tuple(mdef.input_shape)

    def fwd(plist_args, x):
        p = dict(zip(names, plist_args))
        out, _ = mdef.apply(p, x, False)
        return (out,)

    def train_step(plist_args, x, y, lr):
        from .models import common

        p = dict(zip(names, plist_args))
        new_p, loss = common.sgd_train_step(mdef.loss_and_updates, p, x, y, lr)
        # Keep `y` alive even for unsupervised losses (AD ignores labels):
        # jax DCEs unused arguments at lowering, which would change the
        # executable arity the Rust runtime marshals against.
        loss = loss + 0.0 * jnp.sum(y.astype(jnp.float32))
        return tuple(new_p[n] for n in names) + (loss,)

    pspec = tuple(jax.ShapeDtypeStruct(np.asarray(params[n]).shape, jnp.float32) for n in names)

    manifest = {
        "name": mdef.name,
        "task": mdef.task,
        "flow": mdef.flow,
        "input_shape": list(in_shape),
        "num_outputs": mdef.num_outputs,
        "loss_kind": mdef.loss_kind,
        "weight_bits": mdef.weight_bits,
        "params": plist,
        "artifacts": {},
    }

    for tag, batch in (("fwd1", 1), (f"fwd{EVAL_BATCH}", EVAL_BATCH)):
        xspec = jax.ShapeDtypeStruct((batch,) + in_shape, jnp.float32)
        lowered = jax.jit(fwd).lower(pspec, xspec)
        path = f"{mdef.name}_{tag}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"][tag] = {"file": path, "batch": batch}

    if not skip_train:
        tb = mdef.train_batch
        xspec = jax.ShapeDtypeStruct((tb,) + in_shape, jnp.float32)
        if mdef.loss_kind == "ce":
            yspec = jax.ShapeDtypeStruct((tb,), jnp.int32)
        else:
            yspec = jax.ShapeDtypeStruct((tb,), jnp.int32)  # ignored by AD loss
        lrspec = jax.ShapeDtypeStruct((), jnp.float32)
        lowered = jax.jit(train_step).lower(pspec, xspec, yspec, lrspec)
        path = f"{mdef.name}_train.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"]["train"] = {"file": path, "batch": tb}

    topo = mdef.topology()
    with open(os.path.join(out_dir, f"{mdef.name}_topology.json"), "w") as f:
        json.dump(topo, f, indent=1)
    with open(os.path.join(out_dir, f"{mdef.name}_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="all",
                    help="comma list of model names, or 'all'")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    wanted = list(MODELS) if args.models == "all" else args.models.split(",")
    index = {"models": [], "topology_only": []}
    for name in wanted:
        mdef = MODELS[name]
        # IC training graphs are large (interpret-mode conv unrolling); the
        # e2e driver trains AD + KWS for real and IC gets a shorter budget.
        print(f"[aot] exporting {name} ...", flush=True)
        export_model(mdef, args.out)
        index["models"].append(name)

    for topo in topology_only_variants():
        path = f"{topo['name']}_topology.json"
        with open(os.path.join(args.out, path), "w") as f:
            json.dump(topo, f, indent=1)
        index["topology_only"].append(topo["name"])

    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"[aot] wrote {len(index['models'])} models + "
          f"{len(index['topology_only'])} topology-only variants to {args.out}")


if __name__ == "__main__":
    main()
