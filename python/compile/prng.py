"""Deterministic cross-language PRNG (splitmix64 + Box-Muller).

The synthetic datasets substitute for CIFAR-10 / ToyADMOS / Speech Commands
(see DESIGN.md §Hardware-Adaptation).  Training happens in Python at build
time; evaluation happens in Rust on the request path.  Both sides must see
the *same class templates*, so the template generator is a bit-exact
splitmix64 stream mirrored in ``rust/src/data/prng.rs``.  All arithmetic is
u64 wraparound + IEEE-754 f64, which is identical in numpy and Rust.
"""

from __future__ import annotations

import math

import numpy as np

MASK64 = (1 << 64) - 1


class SplitMix64:
    """splitmix64 — tiny, fast, and trivially portable."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53 bits of entropy (matches Rust impl)."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_gaussian(self) -> float:
        """Box-Muller; one sample per call (cosine branch only, portable)."""
        u1 = self.next_f64()
        u2 = self.next_f64()
        # Avoid log(0).
        if u1 <= 0.0:
            u1 = 2.0 ** -53
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def gaussian_vec(self, n: int) -> np.ndarray:
        return np.array([self.next_gaussian() for _ in range(n)], dtype=np.float64)

    def uniform_vec(self, n: int) -> np.ndarray:
        return np.array([self.next_f64() for _ in range(n)], dtype=np.float64)


def template_seed(task_seed: int, class_id: int) -> int:
    """Per-(task, class) stream seed; must match rust/src/data/prng.rs."""
    return (task_seed * 0x100000001B3 + class_id * 0x9E3779B97F4A7C15 + 1) & MASK64


def class_template(task_seed: int, class_id: int, dim: int) -> np.ndarray:
    """The deterministic class template both languages agree on."""
    rng = SplitMix64(template_seed(task_seed, class_id))
    return rng.gaussian_vec(dim)
