"""Synthetic datasets substituting for CIFAR-10 / ToyADMOS / Speech Commands.

See DESIGN.md §Hardware-Adaptation: the paper's datasets are not available
here, so each task gets a parametric dataset of matched shape and tuned
difficulty.  Class *templates* come from the cross-language splitmix64
stream (``prng.py`` == ``rust/src/data/prng.rs``), so Python (training, at
build time) and Rust (evaluation, on the request path) see the same
classes; per-sample noise uses independent streams on each side.

Difficulty is tuned so the fp32 models sit in the high-80s/low-90s accuracy
band (like the paper's reference models) and aggressive quantization
degrades measurably (the Fig. 4 cliff).
"""

from __future__ import annotations

import numpy as np

from .prng import SplitMix64, class_template

IC_SEED = 0xC1FA_0001
AD_SEED = 0x70AD_0002
KWS_SEED = 0x5EEC_0003

IC_CLASSES = 10
IC_DIM = 32 * 32 * 3
KWS_CLASSES = 12
KWS_DIM = 490
KWS_SILENCE = 10
KWS_UNKNOWN = 11
KWS_N_UNKNOWN_TEMPLATES = 25
AD_DIM = 128
AD_SMOOTH_WINDOW = 9

IC_TEMPLATE_SCALE = 0.18
IC_NOISE = 2.0
KWS_NOISE = 1.25
AD_NOISE = 0.35
AD_BUMP_AMP = 1.2
AD_BUMP_WIDTH = 5.0


def _moving_average(x: np.ndarray, window: int) -> np.ndarray:
    """Centered moving average with edge clamping (mirrored in Rust)."""
    n = len(x)
    half = window // 2
    out = np.empty_like(x)
    for i in range(n):
        lo = max(0, i - half)
        hi = min(n, i + half + 1)
        out[i] = np.mean(x[lo:hi])
    return out


# ---------------------------------------------------------------------------
# Templates (identical in Rust).
# ---------------------------------------------------------------------------

def ic_template(c: int) -> np.ndarray:
    return class_template(IC_SEED, c, IC_DIM)


def kws_template(c: int) -> np.ndarray:
    """Classes 0..9 are keywords; 100+j are the 'unknown' sub-templates."""
    return class_template(KWS_SEED, c, KWS_DIM)


def ad_profile(machine_id: int = 0) -> np.ndarray:
    """Normal-operation spectral profile: smoothed gaussian template."""
    raw = class_template(AD_SEED, machine_id, AD_DIM)
    return _moving_average(raw, AD_SMOOTH_WINDOW)


# ---------------------------------------------------------------------------
# Sample generators (Python side uses numpy vectorized noise for speed).
# ---------------------------------------------------------------------------

def ic_batch(rng: np.random.Generator, n: int):
    """Returns (x, y): x in [0,1]^(n, 32, 32, 3), y int32 labels."""
    y = rng.integers(0, IC_CLASSES, size=n)
    templates = np.stack([ic_template(c) for c in range(IC_CLASSES)])
    amp = rng.uniform(0.8, 1.2, size=(n, 1))
    noise = rng.standard_normal((n, IC_DIM))
    x = 0.5 + IC_TEMPLATE_SCALE * (amp * templates[y] + IC_NOISE * noise)
    x = np.clip(x, 0.0, 1.0).astype(np.float32)
    return x.reshape(n, 32, 32, 3), y.astype(np.int32)


def kws_batch(rng: np.random.Generator, n: int):
    """Returns (x, y): x (n, 490) standardized MFCC-like, y int32 in [0,12)."""
    y = rng.integers(0, KWS_CLASSES, size=n)
    x = np.empty((n, KWS_DIM))
    keyword_templates = np.stack([kws_template(c) for c in range(10)])
    unk_templates = np.stack(
        [kws_template(100 + j) for j in range(KWS_N_UNKNOWN_TEMPLATES)]
    )
    for i in range(n):
        noise = rng.standard_normal(KWS_DIM)
        if y[i] < 10:
            x[i] = keyword_templates[y[i]] + KWS_NOISE * noise
        elif y[i] == KWS_SILENCE:
            x[i] = 0.15 * noise
        else:  # unknown: one of 25 off-vocabulary words
            j = rng.integers(0, KWS_N_UNKNOWN_TEMPLATES)
            x[i] = unk_templates[j] + KWS_NOISE * noise
    return x.astype(np.float32), y.astype(np.int32)


def ad_batch(rng: np.random.Generator, n: int, anomalous: bool = False,
             machine_id: int = 0):
    """Returns (x, y): x (n, 128) spectrogram windows, y 0 normal/1 anomaly.

    Anomalies add a localized spectral bump at a random band (a failing
    bearing's resonance) — the ToyADMOS failure signature analogue.
    """
    profile = ad_profile(machine_id)
    noise = rng.standard_normal((n, AD_DIM))
    x = profile[None, :] + AD_NOISE * noise
    if anomalous:
        centers = rng.uniform(8, AD_DIM - 8, size=(n, 1))
        bands = np.arange(AD_DIM)[None, :]
        bump = AD_BUMP_AMP * np.exp(
            -0.5 * ((bands - centers) / AD_BUMP_WIDTH) ** 2
        )
        sign = rng.choice([-1.0, 1.0], size=(n, 1))
        x = x + sign * bump
    y = np.full(n, 1 if anomalous else 0, dtype=np.int32)
    return x.astype(np.float32), y


def batch_for(task: str, rng: np.random.Generator, n: int):
    """Uniform training-batch interface used by aot.py smoke training."""
    if task == "ic":
        x, y = ic_batch(rng, n)
        return x, y
    if task == "kws":
        return kws_batch(rng, n)
    if task == "ad":
        # Train on normal data only (unsupervised, §2.2).
        return ad_batch(rng, n, anomalous=False)
    raise ValueError(task)
