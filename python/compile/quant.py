"""Quantizers for QAT (straight-through estimators) and BatchNorm folding.

Three quantizer families, matching the paper's toolchains:

* ``fixed_point_quant``   — QKeras ``quantized_bits(bits, integer)`` style
  symmetric fixed point, used by the hls4ml models (IC: 8 total / 2 integer,
  AD: 6-12 bits).
* ``int_weight_quant`` / ``uint_act_quant`` — Brevitas-style integer
  quantizers with per-tensor scale, used by the FINN models (KWS W3A3).
* ``bipolar_quant``       — 1-bit {-1,+1} binarization with hard-tanh STE,
  used by CNV-W1A1.

Plus ``fold_bn`` — the QDenseBatchnorm folding of §3.3.1 (eq. 3-4):
``v = gamma / sqrt(var + eps)``, ``k_folded = v * k``,
``b_folded = v * (b - mu) + beta``.  (The paper's text has a typo,
``v = gamma * sqrt(...)``; the division is the standard, correct form and is
what makes folded inference equal BN inference — asserted in the tests.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _ste(x: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Straight-through estimator: forward q, backward identity."""
    return x + jax.lax.stop_gradient(q - x)


def fixed_point_quant(x: jnp.ndarray, bits: int, int_bits: int) -> jnp.ndarray:
    """QKeras-style symmetric fixed point with STE.

    ``bits`` total (incl. sign), ``int_bits`` integer bits (excl. sign).
    Step is ``2^-(bits - 1 - int_bits)``; representable range is
    ``[-2^int_bits, 2^int_bits - step]``.
    """
    frac_bits = bits - 1 - int_bits
    step = 2.0 ** (-frac_bits)
    qmin = -(2.0 ** (bits - 1))
    qmax = 2.0 ** (bits - 1) - 1.0
    q = jnp.clip(jnp.round(x / step), qmin, qmax) * step
    return _ste(x, q)


def int_weight_quant(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Brevitas-style signed int quant, per-tensor dynamic scale, STE."""
    if bits == 1:
        return bipolar_quant(w)
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jax.lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax - 1.0, qmax) * scale
    return _ste(w, q)


def uint_act_quant(x: jnp.ndarray, bits: int, act_range: float = 4.0) -> jnp.ndarray:
    """Unsigned activation quantizer (applied after ReLU), fixed range, STE.

    A fixed ``act_range`` keeps the activation scale static, which is what a
    multi-threshold hardware activation implements (thresholds are baked at
    synthesis time).  ``kernels/multithreshold.py`` realizes exactly this
    function in its inference form; equality is asserted in the tests.
    """
    if bits == 1:
        # Bipolar activation: sign with hard-tanh STE.
        return bipolar_quant(x)
    levels = 2.0 ** bits - 1.0
    step = act_range / levels
    q = jnp.clip(jnp.round(x / step), 0.0, levels) * step
    return _ste(x, q)


def bipolar_quant(x: jnp.ndarray) -> jnp.ndarray:
    """1-bit {-1,+1} binarization; gradient = hard-tanh window (|x| <= 1)."""
    q = jnp.where(x >= 0.0, 1.0, -1.0)
    # STE with gradient clipping outside [-1, 1] (BinaryNet-style).
    clipped = jnp.clip(x, -1.0, 1.0)
    return clipped + jax.lax.stop_gradient(q - clipped)


def fold_bn(kernel, bias, gamma, beta, mean, var, eps: float = 1e-3):
    """Fold BN into the preceding linear layer (paper eq. 3-4, corrected).

    ``kernel`` has output features on the last axis; BN params are 1-D over
    that axis.  Returns ``(k_folded, b_folded)`` such that
    ``x @ k_folded + b_folded == BN(x @ kernel + bias)`` exactly.
    """
    v = gamma / jnp.sqrt(var + eps)
    k_folded = kernel * v  # broadcast over last (output) axis
    b_folded = v * (bias - mean) + beta
    return k_folded, b_folded


def act_thresholds(bits: int, act_range: float = 4.0) -> jnp.ndarray:
    """Thresholds realizing ``uint_act_quant ∘ relu`` as a multi-threshold op.

    out = step * sum_t [x >= th_t]  with  th_t = (t + 0.5) * step,
    t = 0 .. 2^bits - 2.  Matches FINN's streamlined activation.
    """
    levels = int(2**bits - 1)
    step = act_range / levels
    return (jnp.arange(levels, dtype=jnp.float32) + 0.5) * step
