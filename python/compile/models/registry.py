"""Model registry: one ``ModelDef`` per AOT-exported model variant."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from . import ad_autoencoder, ic_finn, ic_hls4ml, kws_mlp


@dataclass
class ModelDef:
    name: str
    task: str           # ic | ad | kws
    flow: str           # hls4ml | finn
    input_shape: tuple
    num_outputs: int
    init_params: Callable[[int], dict]
    apply: Callable     # (params, x, train) -> (out, updates)
    loss_and_updates: Callable
    topology: Callable[[], dict]
    train_batch: int = 32
    loss_kind: str = "ce"   # ce | mse
    weight_bits: str = ""   # for Table 1 reporting


def _kws_def(suffix: str, wbits: int, abits: int) -> ModelDef:
    return ModelDef(
        name=f"kws_mlp_{suffix}",
        task="kws",
        flow="finn",
        input_shape=kws_mlp.INPUT_SHAPE,
        num_outputs=kws_mlp.NUM_OUTPUTS,
        init_params=kws_mlp.init_params,
        apply=kws_mlp.make_apply(wbits, abits),
        loss_and_updates=kws_mlp.make_loss(wbits, abits),
        topology=lambda w=wbits, a=abits: kws_mlp.topology(w, a),
        train_batch=32,
        loss_kind="ce",
        weight_bits="fp32" if wbits >= 32 else str(wbits),
    )


MODELS: dict[str, ModelDef] = {
    "ic_hls4ml": ModelDef(
        name="ic_hls4ml", task="ic", flow="hls4ml",
        input_shape=ic_hls4ml.INPUT_SHAPE, num_outputs=ic_hls4ml.NUM_OUTPUTS,
        init_params=ic_hls4ml.init_params, apply=ic_hls4ml.apply,
        loss_and_updates=ic_hls4ml.loss_and_updates,
        topology=ic_hls4ml.topology, train_batch=16, loss_kind="ce",
        weight_bits="8-12",
    ),
    "ic_finn": ModelDef(
        name="ic_finn", task="ic", flow="finn",
        input_shape=ic_finn.INPUT_SHAPE, num_outputs=ic_finn.NUM_OUTPUTS,
        init_params=ic_finn.init_params, apply=ic_finn.apply,
        loss_and_updates=ic_finn.loss_and_updates,
        topology=ic_finn.topology, train_batch=16, loss_kind="ce",
        weight_bits="1",
    ),
    "ad_autoencoder": ModelDef(
        name="ad_autoencoder", task="ad", flow="hls4ml",
        input_shape=ad_autoencoder.INPUT_SHAPE,
        num_outputs=ad_autoencoder.NUM_OUTPUTS,
        init_params=ad_autoencoder.init_params, apply=ad_autoencoder.apply,
        loss_and_updates=ad_autoencoder.loss_and_updates,
        topology=ad_autoencoder.topology, train_batch=64, loss_kind="mse",
        weight_bits="6-12",
    ),
}
for _suffix, (_w, _a) in kws_mlp.VARIANTS.items():
    MODELS[f"kws_mlp_{_suffix}"] = _kws_def(_suffix, _w, _a)


def get_model(name: str) -> ModelDef:
    return MODELS[name]


def topology_only_variants() -> list[dict]:
    """Topologies that are analyzed (resources/metrics) but never trained:
    the AD Table-4 ablation rows and the full-size CNV-W1A1."""
    return [
        ad_autoencoder.topology_reference(),
        ad_autoencoder.topology_folded(),
        ad_autoencoder.topology_downsampled(),
        ic_finn.topology(full_size=True),
    ]
