"""KWS / FINN — quantized MLP (§3.4), Brevitas-style QAT, WnAm variants.

Input: 490 MFCC features (10 coefficients x 49 frames, 8-bit).  Three FC
layers of 256 units, each followed by BatchNorm and a quantized ReLU, and a
12-way output FC.  Without biases (BN supplies the shift) the parameter
count is 490*256 + 256*256 + 256*256 + 256*12 = 259 584, exactly the paper's
Table 1 figure.  The submitted variant is W3A3 (3-bit weights and
activations, 8-bit input); the Fig. 4 exploration sweeps
W1A1/W2A2/W3A3/W4A4/W8A8/FP32, each exported as its own AOT artifact and
trained *for real* from Rust.

Training uses a weighted cross-entropy that suppresses the "unknown" class
(paper: ~17x over-represented in Speech Commands v2; the suppression weight
mirrors that imbalance).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import quant
from . import common, topology as T

TASK = "kws"
FLOW = "finn"
INPUT_DIM = 490
INPUT_SHAPE = (INPUT_DIM,)
NUM_OUTPUTS = 12
HIDDEN = [256, 256, 256]
UNKNOWN_CLASS = 11
UNKNOWN_WEIGHT = 1.0 / 17.0

VARIANTS = {  # name suffix -> (weight_bits, act_bits); 32 == float
    "w1a1": (1, 1),
    "w2a2": (2, 2),
    "w3a3": (3, 3),
    "w4a4": (4, 4),
    "w8a8": (8, 8),
    "fp32": (32, 32),
}


def _make_quant(wbits: int, abits: int):
    if wbits >= 32:
        wq = lambda w: w
    else:
        wq = lambda w: quant.int_weight_quant(w, wbits)
    if abits >= 32:
        aq = lambda x: jax.nn.relu(x)
    elif abits == 1:
        aq = lambda x: quant.bipolar_quant(x)
    else:
        aq = lambda x: quant.uint_act_quant(jax.nn.relu(x), abits, act_range=4.0)
    return wq, aq


def init_params(seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    params = {}
    dims = [INPUT_DIM] + HIDDEN + [NUM_OUTPUTS]
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:]), start=1):
        key, sub = jax.random.split(key)
        params[f"l{i:02d}_fc.kernel"] = common.he_init(sub, (din, dout), din)
        params[f"l{i:02d}_bn.gamma"] = jnp.ones((dout,), jnp.float32)
        params[f"l{i:02d}_bn.beta"] = jnp.zeros((dout,), jnp.float32)
        params[f"l{i:02d}_bn.mean"] = jnp.zeros((dout,), jnp.float32)
        params[f"l{i:02d}_bn.var"] = jnp.ones((dout,), jnp.float32)
    return params


def make_apply(wbits: int, abits: int):
    wq, aq = _make_quant(wbits, abits)
    n_layers = len(HIDDEN) + 1

    def apply(params: dict, x: jnp.ndarray, train: bool = False):
        updates = {}
        h = quant.uint_act_quant(x, 8, act_range=4.0)  # 8-bit input
        binary = False
        for i in range(1, n_layers + 1):
            h = common.qdense(h, params[f"l{i:02d}_fc.kernel"], wq,
                              binary=(wbits == 1 and binary))
            h, upd = common.batchnorm(params, f"l{i:02d}_bn", h, train)
            updates.update(upd)
            if i < n_layers:
                h = aq(h)
                binary = abits == 1
        return h, updates

    return apply


CLASS_WEIGHTS = jnp.array(
    [1.0] * UNKNOWN_CLASS + [UNKNOWN_WEIGHT], dtype=jnp.float32
)


def make_loss(wbits: int, abits: int):
    apply = make_apply(wbits, abits)

    def loss_and_updates(params, x, y):
        logits, updates = apply(params, x, train=True)
        return common.cross_entropy(logits, y, CLASS_WEIGHTS), updates

    return loss_and_updates


def topology(wbits: int = 3, abits: int = 3) -> dict:
    suffix = "fp32" if wbits >= 32 else f"w{wbits}a{abits}"
    nodes = []
    dims = [INPUT_DIM] + HIDDEN + [NUM_OUTPUTS]
    n_layers = len(dims) - 1
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:]), start=1):
        nodes.append(T.dense(f"l{i:02d}_fc", din, dout, wbits))
        nodes.append(T.batchnorm(f"l{i:02d}_bn", dout))
        if i < n_layers:
            if abits == 1:
                nodes.append(T.bipolar_act(f"l{i:02d}_act", dout))
            else:
                nodes.append(T.relu(f"l{i:02d}_relu", dout, min(abits, 32)))
    return T.model_topology(f"kws_mlp_{suffix}", TASK, FLOW, INPUT_SHAPE, 8, nodes)
