"""IC / FINN — CNV-W1A1 (Umuroglu et al. 2017), width-scaled (§3.2).

Binary (bipolar) weights and activations everywhere except the 8-bit input
layer.  Topology: three conv blocks of two 3x3 VALID convolutions each, max
pooling after the first two blocks, then two hidden FC layers and a 10-way
output; a TopK node computes the classification in hardware (inserted by
the Rust graph pass).  BatchNorm stays a separate graph node — the FINN
streamlining pass (§3.5) folds it into multi-threshold activations.

Width scaling: the paper's CNV uses channels (64, 128, 256) and 512-wide FC
(1 542 848 params); interpret-mode Pallas on one CPU cannot train that, so
we scale to (16, 32, 64) / 128-wide FC (~97 k params) with identical
structure.  Documented in DESIGN.md §Hardware-Adaptation; Table 1 reports
both.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import quant
from . import common, topology as T

NAME = "ic_finn"
TASK = "ic"
FLOW = "finn"
INPUT_SHAPE = (32, 32, 3)
NUM_OUTPUTS = 10

CONV_CH = [16, 16, 32, 32, 64, 64]
FC_DIMS = [128, 128]
# Paper's full-size CNV for Table 1 reporting.
PAPER_PARAMS = 1_542_848


def _wq(w):
    return quant.bipolar_quant(w)


def init_params(seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    params = {}
    in_ch = 3
    for i, ch in enumerate(CONV_CH, start=1):
        key, sub = jax.random.split(key)
        params[f"l{i:02d}_conv.kernel"] = common.he_init(sub, (3, 3, in_ch, ch), 9 * in_ch)
        params[f"l{i:02d}_bn.gamma"] = jnp.ones((ch,), jnp.float32)
        params[f"l{i:02d}_bn.beta"] = jnp.zeros((ch,), jnp.float32)
        params[f"l{i:02d}_bn.mean"] = jnp.zeros((ch,), jnp.float32)
        params[f"l{i:02d}_bn.var"] = jnp.ones((ch,), jnp.float32)
        in_ch = ch
    dims = [CONV_CH[-1]] + FC_DIMS + [NUM_OUTPUTS]
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:]), start=7):
        key, sub = jax.random.split(key)
        params[f"l{i:02d}_fc.kernel"] = common.he_init(sub, (din, dout), din)
        params[f"l{i:02d}_bn.gamma"] = jnp.ones((dout,), jnp.float32)
        params[f"l{i:02d}_bn.beta"] = jnp.zeros((dout,), jnp.float32)
        params[f"l{i:02d}_bn.mean"] = jnp.zeros((dout,), jnp.float32)
        params[f"l{i:02d}_bn.var"] = jnp.ones((dout,), jnp.float32)
    return params


def apply(params: dict, x: jnp.ndarray, train: bool = False):
    """x: (B, 32, 32, 3) in [0, 1]; first layer consumes 8-bit input."""
    updates = {}
    h = quant.uint_act_quant(x, 8, act_range=1.0)
    binary_input = False  # first conv input is 8-bit, not bipolar
    for i in range(1, 7):
        h = common.qconv2d(h, params[f"l{i:02d}_conv.kernel"], _wq,
                           stride=1, padding="VALID", binary=binary_input)
        h, upd = common.batchnorm(params, f"l{i:02d}_bn", h, train)
        updates.update(upd)
        h = quant.bipolar_quant(h)
        binary_input = True
        if i in (2, 4):
            h = common.maxpool2x2(h)
    h = h.reshape(h.shape[0], -1)
    n_fc = 1 + len(FC_DIMS)
    for j in range(n_fc):
        i = 7 + j
        last = j == n_fc - 1
        h = common.qdense(h, params[f"l{i:02d}_fc.kernel"], _wq, binary=True)
        h, upd = common.batchnorm(params, f"l{i:02d}_bn", h, train)
        updates.update(upd)
        if not last:
            h = quant.bipolar_quant(h)
    return h, updates


def loss_and_updates(params, x, y):
    logits, updates = apply(params, x, train=True)
    return common.cross_entropy(logits, y), updates


def topology(full_size: bool = False) -> dict:
    """Our scaled CNV by default; ``full_size=True`` emits the paper's
    (64,128,256)/512 CNV-W1A1 for resource/metric comparison rows."""
    conv_ch = [64, 64, 128, 128, 256, 256] if full_size else CONV_CH
    fc_dims = [512, 512] if full_size else FC_DIMS
    nodes = []
    in_ch, hw = 3, 32
    for i, ch in enumerate(conv_ch, start=1):
        c = T.conv2d(f"l{i:02d}_conv", hw, in_ch, ch, 3, 1, "VALID", 1)
        nodes.append(c)
        nodes.append(T.batchnorm(f"l{i:02d}_bn", ch))
        nodes.append(T.bipolar_act(f"l{i:02d}_act", ch))
        hw, in_ch = c["out_hw"], ch
        if i in (2, 4):
            nodes.append(T.maxpool(f"l{i:02d}_pool", hw, ch, 2))
            hw //= 2
    nodes.append(T.flatten("flatten", hw * hw * in_ch))
    dims = [hw * hw * in_ch] + fc_dims + [NUM_OUTPUTS]
    for j, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        i = 7 + j
        nodes.append(T.dense(f"l{i:02d}_fc", din, dout, 1))
        nodes.append(T.batchnorm(f"l{i:02d}_bn", dout))
        if j < len(dims) - 2:
            nodes.append(T.bipolar_act(f"l{i:02d}_act", dout))
    name = "ic_finn_full" if full_size else NAME
    return T.model_topology(name, TASK, FLOW, INPUT_SHAPE, 8, nodes)
