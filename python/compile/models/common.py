"""Shared L2 building blocks: quantized dense/conv + BN, im2col, losses.

Blocks mirror the paper's two toolchains:

* hls4ml-style ``qdense_bn`` — the QDenseBatchnorm layer of §3.3.1: the FC
  kernel is folded with the BatchNorm parameters *inside the forward pass*
  and quantization is applied to the folded kernel, so QAT sees exactly the
  arithmetic the synthesized design performs.  Running statistics are
  non-trainable params updated by the train step (momentum 0.9).
* FINN-style ``qdense``/``qconv`` + separate ``batchnorm`` — BN is kept as a
  graph node and is *streamlined* into multi-threshold activations by the
  Rust compiler pass (paper §3.5), not folded into (binary) weights.

All dense/conv compute routes through the L1 Pallas kernels
(``kernels.matmul`` / ``kernels.binary_gemm``) so the AOT-lowered HLO
contains the kernel's tiled schedule.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .. import quant
from ..kernels.binary_gemm import binary_gemm_ste
from ..kernels.qmatmul import matmul

BN_EPS = 1e-3
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# Parameter initialization helpers (deterministic, he-normal).
# ---------------------------------------------------------------------------

def he_init(key, shape, fan_in: int) -> jnp.ndarray:
    return jax.random.normal(key, shape, dtype=jnp.float32) * jnp.sqrt(2.0 / fan_in)


# ---------------------------------------------------------------------------
# Weight application: pick the Pallas kernel by weight precision.
# ---------------------------------------------------------------------------

def _qgemm(x: jnp.ndarray, w: jnp.ndarray, wq: Callable[[jnp.ndarray], jnp.ndarray],
           binary: bool) -> jnp.ndarray:
    """Quantize weights (STE) then run the Pallas GEMM.

    For bipolar weights *and* bipolar inputs the XNOR-popcount kernel is
    used; the STE wrapper keeps gradients flowing to the latent f32 weights.
    """
    w_q = wq(w)
    if binary:
        # XNOR-popcount forward, float-product backward (BinaryNet recipe);
        # both directions run on the L1 Pallas kernels.
        return binary_gemm_ste(x, w_q)
    return matmul(x, w_q)


def qdense(x: jnp.ndarray, w: jnp.ndarray, wq, *, binary: bool = False) -> jnp.ndarray:
    """Quantized dense without bias (FINN-style; BN supplies the shift)."""
    return _qgemm(x, w, wq, binary)


def batchnorm(params: dict, prefix: str, y: jnp.ndarray, train: bool):
    """BatchNorm over the last axis; returns (out, stats_updates).

    ``stats_updates`` maps param names to new running stats when training,
    empty when evaluating.
    """
    gamma = params[f"{prefix}.gamma"]
    beta = params[f"{prefix}.beta"]
    if train:
        axes = tuple(range(y.ndim - 1))
        mu = jnp.mean(y, axis=axes)
        # Manual variance: jnp.var's ddof guard lowers to a scalar-pred
        # select-with-NaN that miscompiles on xla_extension 0.5.1.
        var = jnp.mean((y - mu) ** 2, axis=axes)
        new_mean = BN_MOMENTUM * params[f"{prefix}.mean"] + (1 - BN_MOMENTUM) * mu
        new_var = BN_MOMENTUM * params[f"{prefix}.var"] + (1 - BN_MOMENTUM) * var
        updates = {f"{prefix}.mean": new_mean, f"{prefix}.var": new_var}
    else:
        mu = params[f"{prefix}.mean"]
        var = params[f"{prefix}.var"]
        updates = {}
    out = gamma * (y - mu) / jnp.sqrt(var + BN_EPS) + beta
    return out, updates


def qdense_bn(params: dict, prefix: str, x: jnp.ndarray, wq, train: bool):
    """QDenseBatchnorm (§3.3.1): BN folded into the FC kernel pre-quant.

    Training: run the raw FC once to harvest batch statistics, fold BN into
    (kernel, bias) per eq. 3-4, quantize the folded kernel, recompute the
    output with the quantized folded weights.  Inference: fold with running
    stats.  Returns (out, stats_updates).
    """
    k = params[f"{prefix}.kernel"]
    b = params[f"{prefix}.bias"]
    gamma = params[f"{prefix}.gamma"]
    beta = params[f"{prefix}.beta"]
    if train:
        y_raw = matmul(x, k) + b
        mu = jnp.mean(y_raw, axis=0)
        var = jnp.mean((y_raw - mu) ** 2, axis=0)  # see batchnorm() note
        mu_s = jax.lax.stop_gradient(mu)
        var_s = jax.lax.stop_gradient(var)
        updates = {
            f"{prefix}.mean": BN_MOMENTUM * params[f"{prefix}.mean"] + (1 - BN_MOMENTUM) * mu_s,
            f"{prefix}.var": BN_MOMENTUM * params[f"{prefix}.var"] + (1 - BN_MOMENTUM) * var_s,
        }
    else:
        mu, var = params[f"{prefix}.mean"], params[f"{prefix}.var"]
        updates = {}
    k_f, b_f = quant.fold_bn(k, b, gamma, beta, mu, var, BN_EPS)
    out = matmul(x, wq(k_f)) + b_f
    return out, updates


# ---------------------------------------------------------------------------
# Convolution via im2col + Pallas GEMM.
# ---------------------------------------------------------------------------

def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int, padding: str) -> jnp.ndarray:
    """(B, H, W, C) -> (B, OH, OW, kh*kw*C) patches, feature order (i, j, c).

    Matches ``w.reshape(kh*kw*ci, co)`` for HWIO weights; equivalence with
    ``lax.conv_general_dilated`` is asserted in the tests.
    """
    if padding == "SAME":
        ph, pw = (kh - 1) // 2, (kw - 1) // 2
        x = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    b, h, w_, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w_ - kw) // stride + 1
    cols = [
        x[:, i : i + (oh - 1) * stride + 1 : stride, j : j + (ow - 1) * stride + 1 : stride, :]
        for i in range(kh)
        for j in range(kw)
    ]
    return jnp.concatenate(cols, axis=-1)


def qconv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    wq,
    *,
    stride: int = 1,
    padding: str = "VALID",
    binary: bool = False,
) -> jnp.ndarray:
    """Quantized NHWC conv: im2col then the Pallas GEMM. w is HWIO."""
    kh, kw, ci, co = w.shape
    patches = im2col(x, kh, kw, stride, padding)
    b, oh, ow, feat = patches.shape
    flat = patches.reshape(b * oh * ow, feat)
    wmat = w.reshape(kh * kw * ci, co)
    out = _qgemm(flat, wmat, wq, binary)
    return out.reshape(b, oh, ow, co)


def maxpool2x2(x: jnp.ndarray) -> jnp.ndarray:
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


# ---------------------------------------------------------------------------
# Losses.
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  class_weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean (optionally class-weighted) softmax CE; labels are int32.

    Implemented with one-hot contractions rather than ``take_along_axis``:
    jax lowers fancy indexing to a fill-mode gather whose NaN-guard
    miscompiles on the image's xla_extension 0.5.1 (returns NaN for valid
    indices).  One-hot lowers to compare/select + dot, which round-trips
    through the HLO-text interchange cleanly.
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    nll = -jnp.sum(logp * onehot, axis=-1)
    if class_weights is not None:
        wts = jnp.sum(onehot * class_weights[None, :], axis=-1)
        return jnp.sum(nll * wts) / jnp.sum(wts)
    return jnp.mean(nll)


def mse(recon: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((recon - target) ** 2)


# ---------------------------------------------------------------------------
# Generic SGD train step over a params dict with BN-stats side updates.
# ---------------------------------------------------------------------------

def sgd_train_step(loss_and_updates, params: dict, x, y, lr):
    """One SGD step: returns (new_params, loss).

    ``loss_and_updates(params, x, y) -> (loss, stats_updates)``; gradients
    flow only to trainable params (running stats get zero grads and are
    overwritten by ``stats_updates``).
    """

    def lfn(p):
        loss, upd = loss_and_updates(p, x, y)
        return loss, upd

    (loss, updates), grads = jax.value_and_grad(lfn, has_aux=True)(params)
    new = {}
    for name, value in params.items():
        if name in updates:
            new[name] = updates[name]
        elif name.endswith(".mean") or name.endswith(".var"):
            new[name] = value
        else:
            new[name] = value - lr * grads[name]
    return new, loss
