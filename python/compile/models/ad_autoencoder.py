"""AD / hls4ml — quantized autoencoder (§3.3) with QDenseBatchnorm.

Submitted model: 128 inputs (mel-spectrogram window downsampled from 640),
encoder/decoder of two quantized 72-unit FC layers each (QDenseBatchnorm +
ReLU), an 8-wide bottleneck, and a linear 128-wide output FC.  Weights are
6-bit fixed point, activations 8-bit (paper: "6-12 bits").  Anomaly score =
MSE(input, reconstruction); threshold calibration + AUC live in Rust
(`data::roc_auc`).

Table 4 variants (reference 640-input 9x128 model, folding-only,
downsampling-only) are emitted as *topologies only* — exactly like the
paper, where the reference floating-point model was too large to
synthesize.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import quant
from . import common, topology as T

NAME = "ad_autoencoder"
TASK = "ad"
FLOW = "hls4ml"
INPUT_DIM = 128
INPUT_SHAPE = (INPUT_DIM,)
NUM_OUTPUTS = INPUT_DIM
HIDDEN = [72, 72, 8, 72, 72]  # 5 hidden layers (paper: 9 -> 5, 128 -> 72)
W_BITS, W_INT = 6, 2
A_BITS = 8


def _wq(w):
    return quant.fixed_point_quant(w, W_BITS, W_INT)


def _aq(x):
    return quant.uint_act_quant(x, A_BITS, act_range=4.0)


def init_params(seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    params = {}
    dims = [INPUT_DIM] + HIDDEN
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:]), start=1):
        key, sub = jax.random.split(key)
        params[f"l{i:02d}_fc.kernel"] = common.he_init(sub, (din, dout), din)
        params[f"l{i:02d}_fc.bias"] = jnp.zeros((dout,), jnp.float32)
        params[f"l{i:02d}_fc.gamma"] = jnp.ones((dout,), jnp.float32)
        params[f"l{i:02d}_fc.beta"] = jnp.zeros((dout,), jnp.float32)
        params[f"l{i:02d}_fc.mean"] = jnp.zeros((dout,), jnp.float32)
        params[f"l{i:02d}_fc.var"] = jnp.ones((dout,), jnp.float32)
    key, sub = jax.random.split(key)
    params["l06_out.kernel"] = common.he_init(sub, (HIDDEN[-1], INPUT_DIM), HIDDEN[-1])
    params["l06_out.bias"] = jnp.zeros((INPUT_DIM,), jnp.float32)
    return params


def apply(params: dict, x: jnp.ndarray, train: bool = False):
    """x: (B, 128) standardized mel-band window; returns reconstruction."""
    updates = {}
    h = x
    for i in range(1, len(HIDDEN) + 1):
        h, upd = common.qdense_bn(params, f"l{i:02d}_fc", h, _wq, train)
        updates.update(upd)
        h = _aq(jax.nn.relu(h))
    recon = common.matmul(h, _wq(params["l06_out.kernel"])) + params["l06_out.bias"]
    return recon, updates


def loss_and_updates(params, x, y):
    """Unsupervised: y is ignored (kept for the uniform train-step ABI)."""
    recon, updates = apply(params, x, train=True)
    return common.mse(recon, x), updates


def _mlp_topology(name, input_dim, hidden, wbits, folded: bool, rf: int) -> dict:
    nodes = []
    dims = [input_dim] + hidden
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:]), start=1):
        nodes.append(T.dense(f"l{i:02d}_fc", din, dout, wbits, has_bias=True))
        nodes.append(T.batchnorm(f"l{i:02d}_bn", dout))
        nodes.append(T.relu(f"l{i:02d}_relu", dout, A_BITS))
    nodes.append(T.dense("l06_out", hidden[-1], input_dim, wbits, has_bias=True))
    return T.model_topology(name, TASK, FLOW, (input_dim,), 8, nodes,
                            folded_bn=folded, reuse_factor=rf)


def topology() -> dict:
    """Submitted model: downsampled input + folded BN + RF 144 (§3.3.2)."""
    return _mlp_topology(NAME, INPUT_DIM, HIDDEN, W_BITS, True, 144)


def topology_reference() -> dict:
    """MLPerf Tiny AD reference: 640 inputs, 9 hidden FC(128) + bottleneck.

    Float32 weights (wbits 32) — too large to synthesize (Table 4 row 1)."""
    hidden = [128, 128, 128, 128, 8, 128, 128, 128, 128]
    return _mlp_topology("ad_reference", 640, hidden, 32, False, 144)


def topology_folded() -> dict:
    """Reference arch, quantized + BN folded, still 640 inputs (row 2)."""
    hidden = [128, 128, 128, 128, 8, 128, 128, 128, 128]
    return _mlp_topology("ad_folded", 640, hidden, W_BITS, True, 144)


def topology_downsampled() -> dict:
    """128 inputs, reference-width layers, no folding yet (row 3)."""
    hidden = [128, 128, 128, 128, 8, 128, 128, 128, 128]
    return _mlp_topology("ad_downsampled", 128, hidden, W_BITS, False, 144)
