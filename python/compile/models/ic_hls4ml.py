"""IC / hls4ml — the v0.7 2-stack NAS winner (§3.1.1), QKeras 8-bit QAT.

Architecture (from the BO scan description): 5 conv layers with filters
[32, 4, 32, 32, 32], kernel sizes [1, 4, 4, 4, 4], strides [1, 1, 1, 4, 1],
no skip connections, followed by one FC layer over the flattened 8x8x32 =
2048 features ("an FC layer with 2048 units") to 10 classes.  The paper's
listing (final conv "4 filters") is inconsistent with both its own 58 115
parameter count and the 2048-unit FC; this reconstruction hits ~58 k params
and the 2048-wide FC simultaneously.  Softmax is removed for inference
(monotonic; §3.1.1) — the Rust graph pass inserts TopK instead.

Weights/activations: fixed-point QAT, 8 total / 2 integer bits (QKeras
``quantized_bits(8, 2)``), activations 8-bit unsigned after ReLU.  The FC is
a QDenseBatchnorm (§3.3.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import quant
from . import common, topology as T

NAME = "ic_hls4ml"
TASK = "ic"
FLOW = "hls4ml"
INPUT_SHAPE = (32, 32, 3)
NUM_OUTPUTS = 10

FILTERS = [32, 4, 32, 32, 32]
KERNELS = [1, 4, 4, 4, 4]
STRIDES = [1, 1, 1, 4, 1]
W_BITS, W_INT = 8, 2
A_BITS = 8


def _wq(w):
    return quant.fixed_point_quant(w, W_BITS, W_INT)


def _aq(x):
    return quant.uint_act_quant(x, A_BITS, act_range=4.0)


def init_params(seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    params = {}
    in_ch = 3
    for i, (f, k) in enumerate(zip(FILTERS, KERNELS), start=1):
        key, sub = jax.random.split(key)
        params[f"l{i:02d}_conv.kernel"] = common.he_init(sub, (k, k, in_ch, f), k * k * in_ch)
        params[f"l{i:02d}_bn.gamma"] = jnp.ones((f,), jnp.float32)
        params[f"l{i:02d}_bn.beta"] = jnp.zeros((f,), jnp.float32)
        params[f"l{i:02d}_bn.mean"] = jnp.zeros((f,), jnp.float32)
        params[f"l{i:02d}_bn.var"] = jnp.ones((f,), jnp.float32)
        in_ch = f
    flat = 8 * 8 * FILTERS[-1]
    key, sub = jax.random.split(key)
    params["l06_fc.kernel"] = common.he_init(sub, (flat, NUM_OUTPUTS), flat)
    params["l06_fc.bias"] = jnp.zeros((NUM_OUTPUTS,), jnp.float32)
    params["l06_fc.gamma"] = jnp.ones((NUM_OUTPUTS,), jnp.float32)
    params["l06_fc.beta"] = jnp.zeros((NUM_OUTPUTS,), jnp.float32)
    params["l06_fc.mean"] = jnp.zeros((NUM_OUTPUTS,), jnp.float32)
    params["l06_fc.var"] = jnp.ones((NUM_OUTPUTS,), jnp.float32)
    return params


def apply(params: dict, x: jnp.ndarray, train: bool = False):
    """x: (B, 32, 32, 3) in [0, 1] (the /256 normalization of §3.1.1)."""
    updates = {}
    h = quant.uint_act_quant(x, 8, act_range=1.0)  # 8-bit input
    for i, (k, s) in enumerate(zip(KERNELS, STRIDES), start=1):
        h = common.qconv2d(h, params[f"l{i:02d}_conv.kernel"], _wq,
                           stride=s, padding="SAME")
        h, upd = common.batchnorm(params, f"l{i:02d}_bn", h, train)
        updates.update(upd)
        h = _aq(jax.nn.relu(h))
    h = h.reshape(h.shape[0], -1)
    logits, upd = common.qdense_bn(params, "l06_fc", h, _wq, train)
    updates.update(upd)
    return logits, updates


def loss_and_updates(params, x, y):
    logits, updates = apply(params, x, train=True)
    return common.cross_entropy(logits, y), updates


def topology() -> dict:
    nodes = []
    in_ch, hw = 3, 32
    for i, (f, k, s) in enumerate(zip(FILTERS, KERNELS, STRIDES), start=1):
        c = T.conv2d(f"l{i:02d}_conv", hw, in_ch, f, k, s, "SAME", W_BITS)
        nodes.append(c)
        nodes.append(T.batchnorm(f"l{i:02d}_bn", f))
        nodes.append(T.relu(f"l{i:02d}_relu", f, A_BITS))
        hw, in_ch = c["out_hw"], f
    nodes.append(T.flatten("flatten", hw * hw * in_ch))
    nodes.append(T.dense("l06_fc", hw * hw * in_ch, NUM_OUTPUTS, W_BITS, has_bias=True))
    nodes.append(T.batchnorm("l06_bn", NUM_OUTPUTS))
    nodes.append(T.softmax("softmax", NUM_OUTPUTS))
    return T.model_topology(NAME, TASK, FLOW, INPUT_SHAPE, 8, nodes)
