"""L2 — the paper's four submitted models (Table 1) as pure-JAX functions.

| name            | task | flow   | precision        | paper params |
|-----------------|------|--------|------------------|--------------|
| ic_hls4ml       | IC   | hls4ml | 8-12 bit fixed   | 58 115       |
| ic_finn         | IC   | FINN   | 1 bit (bipolar)  | 1 542 848 (†)|
| ad_autoencoder  | AD   | hls4ml | 6-12 bit fixed   | 22 285       |
| kws_mlp_w3a3    | KWS  | FINN   | 3 bit int        | 259 584      |

(†) our CNV is width-scaled for 1-CPU tractability; see DESIGN.md
§Hardware-Adaptation.  ``kws_mlp`` also exists in W1A1..W8A8 + FP32
variants for the Fig. 4 quantization exploration.
"""

from .registry import MODELS, ModelDef, get_model, topology_only_variants

__all__ = ["MODELS", "ModelDef", "get_model", "topology_only_variants"]
