"""Topology (QONNX-like IR) node constructors.

``aot.py`` writes one ``*_topology.json`` per model; the Rust compiler
(`rust/src/ir`) parses it, runs the optimization passes of §3 on it, and
feeds the dataflow simulator + resource estimators.  The schema is a plain
chain of nodes (all four submitted models are chains — the chosen v0.7 IC
model has no skip connections, §3.1.1).
"""

from __future__ import annotations


def conv2d(name, in_hw, in_ch, out_ch, kernel, stride, padding, weight_bits,
           out_hw=None):
    if out_hw is None:
        if padding == "SAME":
            out_hw = (in_hw + stride - 1) // stride
        else:
            out_hw = (in_hw - kernel) // stride + 1
    return {
        "op": "Conv2D", "name": name, "in_hw": in_hw, "out_hw": out_hw,
        "in_ch": in_ch, "out_ch": out_ch, "kernel": kernel, "stride": stride,
        "padding": padding, "weight_bits": weight_bits,
        "params": kernel * kernel * in_ch * out_ch,
    }


def dense(name, in_features, out_features, weight_bits, has_bias=False):
    return {
        "op": "Dense", "name": name, "in_features": in_features,
        "out_features": out_features, "weight_bits": weight_bits,
        "has_bias": has_bias,
        "params": in_features * out_features + (out_features if has_bias else 0),
    }


def batchnorm(name, channels):
    return {"op": "BatchNorm", "name": name, "channels": channels,
            "params": 4 * channels}


def relu(name, channels, act_bits):
    return {"op": "ReLU", "name": name, "channels": channels,
            "act_bits": act_bits, "params": 0}


def bipolar_act(name, channels):
    return {"op": "BipolarAct", "name": name, "channels": channels,
            "params": 0}


def maxpool(name, in_hw, channels, size):
    return {"op": "MaxPool", "name": name, "in_hw": in_hw,
            "out_hw": in_hw // size, "channels": channels, "size": size,
            "params": 0}


def flatten(name, features):
    return {"op": "Flatten", "name": name, "features": features, "params": 0}


def softmax(name, channels):
    return {"op": "Softmax", "name": name, "channels": channels, "params": 0}


def model_topology(name, task, flow, input_shape, input_bits, nodes,
                   folded_bn=False, reuse_factor=1):
    return {
        "name": name, "task": task, "flow": flow,
        "input_shape": list(input_shape), "input_bits": input_bits,
        "folded_bn": folded_bn, "reuse_factor": reuse_factor,
        "nodes": nodes,
        "total_params": sum(n["params"] for n in nodes),
    }
