"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every Pallas kernel in this package has an independent reference here; the
pytest + hypothesis suite sweeps shapes/dtypes and asserts allclose.  The
binary GEMM oracle deliberately uses the *XNOR-popcount* formulation (what
the FPGA datapath computes) rather than a float dot product, so the test
also proves the popcount equivalence FINN relies on.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """f32 GEMM oracle."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def binary_gemm_ref(xb: jnp.ndarray, wb: jnp.ndarray) -> jnp.ndarray:
    """XNOR-popcount binary GEMM oracle over bipolar {-1,+1} inputs.

    With a, b in {-1,+1}^K:  dot(a, b) = K - 2 * popcount(a_bits XOR b_bits),
    where x_bits = (x + 1) / 2.  This is the datapath FINN synthesizes into
    LUTs; the Pallas kernel computes the same quantity.
    """
    k = xb.shape[-1]
    x_bits = (xb > 0.0).astype(jnp.int32)  # (M, K)
    w_bits = (wb > 0.0).astype(jnp.int32)  # (K, N)
    # popcount(xor) across K for every (m, n) pair.
    xor = jnp.bitwise_xor(x_bits[:, None, :], w_bits.T[None, :, :])  # (M, N, K)
    pop = jnp.sum(xor, axis=-1)
    return (k - 2 * pop).astype(jnp.float32)


def multithreshold_ref(x: jnp.ndarray, thresholds: jnp.ndarray) -> jnp.ndarray:
    """Multi-threshold oracle: out[b, c] = sum_t [x[b, c] >= th[c, t]].

    FINN's streamlined quantized activation (Umuroglu & Jahre 2017): any
    uniform quantized monotone activation is a sum of step functions.
    """
    return jnp.sum(
        (x[:, :, None] >= thresholds[None, :, :]).astype(jnp.float32), axis=-1
    )


def conv2d_nhwc_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int, padding: str) -> jnp.ndarray:
    """Direct NHWC conv oracle via lax (independent of the im2col path)."""
    import jax

    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
