"""Pallas XNOR-popcount binary GEMM — the CNV-W1A1 (FINN) hot loop.

On the FPGA, a binary MVAU computes ``dot(a, b) = K - 2*popcount(a XOR b)``
entirely in LUTs (no DSPs — cf. Table 5: IC/FINN uses 0 DSPs on Pynq-Z2).
The Pallas kernel computes the identical quantity from the bit-plane form:
inputs are bipolar {-1,+1} floats, the kernel recovers the bit planes,
accumulates the XOR-popcount per K-tile, and converts back to the signed
dot product.  The oracle in ``ref.py`` evaluates the same formula with an
explicit (M, N, K) xor tensor, so the tiled kernel is checked against an
independently-shaped computation.

TPU mapping (DESIGN.md §Hardware-Adaptation): the popcount reduction is a
1-bit matmul; on a real TPU this feeds the MXU as bf16 ±1 multiplies, with
the XOR trick recovered by the compiler through the affine substitution
x = 2*xb - 1.  Structure (tiling, revolving accumulator) is shared with
``qmatmul.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .qmatmul import _pad_to


def _binary_kernel(x_ref, w_ref, o_ref, *, k_total: int, bk: int, n_k: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Bit planes: {-1,+1} -> {0,1}.  Padding contributed 0.0 which maps to
    # bit 0; pad columns of x and pad rows of w then XOR to 0^0 = 0 and the
    # popcount correction below must only count *real* K, handled by the
    # caller passing the true k_total.
    xb = (x_ref[...] > 0.0).astype(jnp.float32)
    wb = (w_ref[...] > 0.0).astype(jnp.float32)
    # popcount(xor) = sum(xb + wb - 2*xb*wb) = sum_xb + sum_wb - 2*dot.
    dot = jnp.dot(xb, wb, preferred_element_type=jnp.float32)
    sum_x = jnp.sum(xb, axis=1, keepdims=True)  # (bm, 1)
    sum_w = jnp.sum(wb, axis=0, keepdims=True)  # (1, bn)
    pop = sum_x + sum_w - 2.0 * dot
    # Accumulate -2*popcount; add K once (on the last tile).
    o_ref[...] += -2.0 * pop

    @pl.when(kk == n_k - 1)
    def _finish():
        o_ref[...] += jnp.float32(k_total)


def binary_gemm(
    xb: jnp.ndarray,
    wb: jnp.ndarray,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    """XNOR-popcount GEMM over bipolar inputs; returns f32 signed dot.

    Zero padding is safe: a padded x column is bit 0 and the matching padded
    w row is bit 0, so xor = 0 and the popcount is unaffected; the +K
    correction uses the unpadded K.
    """
    m, k = xb.shape
    k2, n = wb.shape
    assert k == k2
    bm = min(bm, max(1, m))
    bn = min(bn, max(1, n))
    bk = min(bk, max(1, k))
    xp = _pad_to(_pad_to(xb, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(wb, 0, bk), 1, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_binary_kernel, k_total=k, bk=bk, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp.astype(jnp.float32), wp.astype(jnp.float32))
    return out[:m, :n]


@jax.custom_vjp
def binary_gemm_ste(xb: jnp.ndarray, wb: jnp.ndarray) -> jnp.ndarray:
    """Differentiable XNOR-popcount GEMM.

    For bipolar inputs ``binary_gemm(x, w) == x @ w`` exactly (proved by the
    kernel-vs-oracle tests), so the float-product cotangents are the correct
    gradients: ``dx = g @ wᵀ``, ``dw = xᵀ @ g`` — both routed through the
    Pallas f32 kernel.  This is the BinaryNet training recipe: binary
    forward, real-valued backward.
    """
    return binary_gemm(xb, wb)


def _bg_fwd(xb, wb):
    return binary_gemm(xb, wb), (xb, wb)


def _bg_bwd(res, g):
    from .qmatmul import matmul_untiled

    xb, wb = res
    return matmul_untiled(g, wb.T), matmul_untiled(xb.T, g)


binary_gemm_ste.defvjp(_bg_fwd, _bg_bwd)
