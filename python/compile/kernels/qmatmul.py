"""Pallas tiled GEMM — the MVAU (matrix-vector-activation unit) hot loop.

This is the compute hot-spot of every dataflow layer in the paper: an FPGA
MVAU streams activation vectors against a weight matrix with PE x SIMD
parallelism.  On TPU the same insight maps to MXU tiles: BlockSpec expresses
the HBM->VMEM schedule that the FPGA did with on-chip weight BRAMs and
activation FIFOs (see DESIGN.md §Hardware-Adaptation).

Grid is (M/bm, N/bn, K/bk) with a revolving f32 accumulator in the output
block; the K axis is innermost so each (i, j) output tile stays resident in
VMEM while weight tiles stream through — the double-buffered schedule the
paper's reuse-factor knob controls on the FPGA.

MUST run with ``interpret=True``: the CPU PJRT client cannot execute Mosaic
custom-calls (real-TPU lowering).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """One (bm, bn) output tile; accumulates over the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def matmul_untiled(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    """``x @ w`` via the Pallas MVAU kernel with zero-padding to tile shape.

    Block shapes are multiples of the MXU-friendly (8, 128) (sublane, lane)
    tiling; the default (256, 256, 512) keeps one x-tile + one w-tile + one
    f32 accumulator tile at ~(256*512 + 512*256 + 256*256)*4B ~ 1.3 MB,
    comfortably inside a 16 MB VMEM budget while amortizing interpret-mode
    grid overhead (the FPGA analogue of the reuse factor: how many MACs
    share one multiplier).

    AOT note: exported HLO text MUST be printed with
    ``print_large_constants=True`` — the default printer elides big array
    constants to ``{...}`` and xla_extension 0.5.1 silently parses the
    elision as NaN (DESIGN.md §Known-substrate-gotchas).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} != {k2}"
    bm = min(bm, max(1, m))
    bn = min(bn, max(1, n))
    bk = min(bk, max(1, k))
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp.astype(jnp.float32), wp.astype(jnp.float32))
    return out[:m, :n]


@jax.custom_vjp
def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Differentiable Pallas GEMM.

    ``pallas_call`` has no automatic VJP, so the backward pass is spelled
    out — and itself routed through the Pallas kernel, keeping *all* GEMM
    work (fwd and bwd) on the L1 hot path:
    ``dx = g @ wᵀ``, ``dw = xᵀ @ g``.
    """
    return matmul_untiled(x, w)


def _matmul_fwd(x, w):
    return matmul_untiled(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    return matmul_untiled(g, w.T), matmul_untiled(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
