"""L1 — Pallas kernels for the quantized dataflow hot-spots.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness vs the pure-jnp oracles in ``ref.py`` is the
build-time gate (`make test`).
"""

from .qmatmul import matmul
from .binary_gemm import binary_gemm
from .multithreshold import multithreshold

__all__ = ["matmul", "binary_gemm", "multithreshold"]
