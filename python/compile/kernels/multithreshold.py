"""Pallas multi-threshold activation — FINN's streamlined quantized ReLU.

Streamlining (Umuroglu & Jahre 2017, paper §3.5) folds BN + uniform
quantized activations into a single integer multi-threshold node:
``out[b, c] = step * sum_t [x[b, c] >= th[c, t]]``.  On the FPGA this is a
comparator tree per channel; here it is a Pallas kernel tiled over the batch
with the full (C, T) threshold plane resident (thresholds are tiny: C x
(2^bits - 1) entries).

``quant.act_thresholds`` produces the thresholds that make this node exactly
equal to ``uint_act_quant(relu(x))`` — asserted in the tests, which is the
streamlining-correctness proof the paper's flow relies on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .qmatmul import _pad_to


def _mt_kernel(x_ref, th_ref, o_ref):
    x = x_ref[...]  # (bb, C)
    th = th_ref[...]  # (C, T)
    hits = (x[:, :, None] >= th[None, :, :]).astype(jnp.float32)
    o_ref[...] = jnp.sum(hits, axis=-1)


def multithreshold(x: jnp.ndarray, thresholds: jnp.ndarray, *, bb: int = 64) -> jnp.ndarray:
    """Apply per-channel thresholds; returns integer level counts as f32.

    ``x`` is (B, C); ``thresholds`` is (C, T) with rows sorted ascending.
    """
    b, c = x.shape
    c2, t = thresholds.shape
    assert c == c2
    bb = min(bb, max(1, b))
    xp = _pad_to(x, 0, bb)
    bp = xp.shape[0]
    out = pl.pallas_call(
        _mt_kernel,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
            pl.BlockSpec((c, t), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, c), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), thresholds.astype(jnp.float32))
    return out[:b]
