#!/usr/bin/env sh
# Doc link-integrity gate: every relative markdown link and every
# backtick-quoted repo path in the operator docs must resolve to a real
# file, so the docs cannot silently rot as the tree moves underneath
# them.  Scans docs/*.md plus ROADMAP.md; needs only POSIX sh + grep +
# sed (no Rust toolchain), so it runs first in CI and on any host.
#
#   ./tools/check_docs.sh
#
# Checked, per file:
#   1. [text](target)  -- relative links, resolved against the doc's own
#                         directory and then the repo root; #fragment
#                         suffixes are stripped; http(s)/mailto targets
#                         are skipped (this is an offline image).
#   2. `path/to/file`  -- backtick tokens that start with a known
#                         top-level directory (rust/ benches/ baselines/
#                         tools/ docs/ examples/) must exist on disk.
#                         Tokens containing globs or prose metacharacters
#                         are skipped: `baselines/BENCH_*.json` is a
#                         pattern, not a path.
set -eu
cd "$(dirname "$0")/.."

status=0
docs="ROADMAP.md"
for f in docs/*.md; do
    [ -e "$f" ] && docs="$docs $f"
done

for doc in $docs; do
    dir=$(dirname "$doc")

    # Markdown link targets.  Doc links in this repo never contain
    # spaces, so plain word-splitting of the extracted list is safe.
    links=$(grep -o '](  *[^)]*)\|]([^)]*)' "$doc" | sed 's/^]( *//; s/)$//' || true)
    for target in $links; do
        case "$target" in
            http://* | https://* | mailto:* | '#'*) continue ;;
        esac
        path=${target%%#*}
        [ -n "$path" ] || continue
        if [ ! -e "$dir/$path" ] && [ ! -e "$path" ]; then
            echo "check_docs: $doc: broken link ($target)" >&2
            status=1
        fi
    done

    # Backtick-quoted repo paths.
    refs=$(grep -o '`[^` ]*`' "$doc" | tr -d '\140' || true)
    for ref in $refs; do
        case "$ref" in
            rust/* | benches/* | baselines/* | tools/* | docs/* | examples/*) ;;
            *) continue ;;
        esac
        case "$ref" in
            *'*'* | *'{'* | *'('* | *'<'* | *..*) continue ;;
        esac
        if [ ! -e "$ref" ]; then
            echo "check_docs: $doc: dangling path reference ($ref)" >&2
            status=1
        fi
    done
done

if [ "$status" -ne 0 ]; then
    echo "check_docs: FAILED" >&2
    exit 1
fi
echo "check_docs: OK ($(echo "$docs" | wc -w | tr -d ' ') files checked)"
