#!/usr/bin/env sh
# Bench-regression gate: diff freshly emitted BENCH_kernels.json /
# BENCH_fleet.json (run `./ci.sh` or the benches first) against the
# committed baselines in baselines/ and fail on a >10% regression of any
# headline ratio.  Thin wrapper over the in-tree implementation
# (rust/src/report/gate.rs) so CI and humans share one code path.
#
#   ./tools/bench_gate.sh                 # gate current BENCH_* vs baselines/
#   ./tools/bench_gate.sh --self-test     # prove the gate rejects regressions
#   ./tools/bench_gate.sh --update        # bless current BENCH_* as baselines
#   ./tools/bench_gate.sh --tol 0.05      # tighter tolerance
set -eu
cd "$(dirname "$0")/.."
exec cargo run --release --quiet --bin tinyml-codesign -- bench-gate "$@"
