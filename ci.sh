#!/usr/bin/env sh
# CI gate: build, tests, formatting, lints.  Run from the repo root.
#
#   ./ci.sh          # everything
#   ./ci.sh fast     # build + tests only (skip fmt/clippy)
#
# The crate is dependency-free by design (offline build image), so a bare
# rust toolchain is all this needs.  fmt/clippy steps are skipped with a
# warning when the components are not installed.

set -eu

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH — install a Rust toolchain first" >&2
    exit 1
fi

run() {
    echo "==> $*"
    "$@"
}

# Doc link integrity first: needs no toolchain, fails fast, and covers
# docs/*.md + ROADMAP.md (relative links and backtick path references).
run ./tools/check_docs.sh

run cargo build --release
run cargo test -q

if [ "${1:-}" = "fast" ]; then
    echo "==> skipping kernels+fleet+hotpath+scenarios benches, bench gate, chaos smoke, cargo doc, pjrt check, fmt/clippy (fast mode)"
    exit 0
fi

# Scalar-oracle rerun: the TINYML_FORCE_SCALAR=1 kill switch must pin
# the kernel dispatch to the scalar path and keep it healthy on any
# host CPU.  Rerun the kernel unit tests, the packed/simd proptests,
# and the quick kernels bench under the switch (the forced-scalar bench
# emits simd_unavailable: true so its floors self-skip; it runs BEFORE
# the dispatched bench below so the BENCH_kernels.json the gate reads
# comes from the real SIMD run).
run env TINYML_FORCE_SCALAR=1 cargo test -q --lib -- kernels
run env TINYML_FORCE_SCALAR=1 cargo test -q --test proptests -- packed simd
run env TINYML_FORCE_SCALAR=1 BENCH_QUICK=1 cargo bench --bench kernels

# Kernel-core self-check: quick mode keeps the perf-floor and
# equivalence assertions but cuts iterations ~10x.  Emits
# BENCH_kernels.json (the recorded perf trajectory), now including the
# simd-vs-scalar-oracle A/B (simd_over_scalar_speedup per shape).
run env BENCH_QUICK=1 cargo bench --bench kernels

# Fleet self-check: routing-policy floor (least-loaded >= round-robin),
# the autoscale guarantee (elastic p99 <= fixed 6-board p99 on fewer
# board-seconds, no dropped requests), and the priority-scheduling floor
# (interactive p99 <= 0.5x the FIFO control, zero interactive sheds).
# Emits BENCH_fleet.json.
run env BENCH_QUICK=1 cargo bench --bench fleet

# Hot-path self-check: 8-client submit saturation, lock-sharded
# telemetry + striped cache + pooled replies vs the global-lock A/B
# plane (floor: >= 1.3x throughput on >= 4 hardware threads; the
# telemetry merge-equivalence assertions run regardless), plus the
# lifecycle-tracing leg (1-in-16 sampling >= 0.9x untraced).  Emits
# BENCH_hotpath.json.
run env BENCH_QUICK=1 cargo bench --bench hotpath

# Resilience self-check: seeded kill / brownout / flash-crowd scenarios
# against the chaos+health+retry plane (floors: zero lost requests and
# an automatic ejection under a single-replica kill, brownout p99 within
# 8x the healthy control, flash crowd >= 0.95 served on a degraded
# fleet).  Emits BENCH_scenarios.json.
run env BENCH_QUICK=1 cargo bench --bench scenarios

# Chaos smoke: a fleet run with a seeded replica kill must eject the
# victim and still resolve every admitted request (the machine-parseable
# `chaos:` line carries ejections/served/failed/lost).
echo "==> fleet --chaos kill=0@2 | ejection + conservation check"
cargo run --release -q -- fleet --chaos kill=0@2 --requests 200 \
  | awk '/^chaos: /{ line=$0 }
         END {
           if (line == "") { print "no chaos: line in fleet output"; exit 1 }
           print "==> " line
           if (line !~ /lost=0$/)       { print "chaos smoke: lost requests"; exit 1 }
           if (line ~ /ejections=0 /)   { print "chaos smoke: no ejection"; exit 1 }
         }'

# Coalescing smoke: the fleet CLI's mixed workload submits a constant
# input per task open-loop, so with single-flight coalescing on, the
# duplicates still in flight must attach as followers (followers > 0 on
# the machine-parseable `coalesce:` line) and every follower must fan
# cleanly (fanned_err = 0 — no chaos in this run).
echo "==> fleet --coalesce --cache 256 | follower fan-out check"
cargo run --release -q -- fleet --coalesce --cache 256 --requests 200 \
  | awk '/^coalesce: /{ line=$0 }
         END {
           if (line == "") { print "no coalesce: line in fleet output"; exit 1 }
           print "==> " line
           if (line !~ /followers=[1-9]/) { print "coalesce smoke: no followers attached"; exit 1 }
           if (line !~ /fanned_err=0$/)   { print "coalesce smoke: follower fan-out failed"; exit 1 }
         }'

# Deadline smoke: a browned-out fleet run with per-request deadlines and
# hedging armed must keep dead work off the boards — the machine-parseable
# `deadline:` line must show executed_expired=0 (whatever expires is
# discarded at a stage boundary, never executed).
echo "==> fleet --chaos slow=4x0 --deadline-us 80000 --hedge-p99 2.0 | dead-work check"
cargo run --release -q -- fleet --chaos slow=4x0 --deadline-us 80000 --hedge-p99 2.0 \
    --requests 200 \
  | awk '/^deadline: /{ line=$0 }
         END {
           if (line == "") { print "no deadline: line in fleet output"; exit 1 }
           print "==> " line
           if (line !~ /executed_expired=0$/) { print "deadline smoke: a board executed expired work"; exit 1 }
         }'

# Tracing smoke: a sampled fleet run must round-trip (stage histograms,
# drift, and shed reasons ride the normal report), and the event-ring
# dump must be valid JSONL — every non-empty line parses as one strict
# JSON object (the binary self-checks each line too; this re-checks at
# the consumer's side of the pipe).
run cargo run --release -q -- fleet --trace-sample 16 --requests 200 > /dev/null
echo "==> fleet --trace-sample 16 --trace-dump | JSONL parse check"
cargo run --release -q -- fleet --trace-sample 16 --requests 200 --trace-dump \
  | awk 'NF { if ($0 !~ /^\{.*\}$/) { print "bad JSONL line: " $0; exit 1 } n++ } END { print "==> trace-dump: " n+0 " JSONL event lines" }'

# Bench-regression gate: first prove the gate rejects injected
# regressions (self-test), then hold the freshly emitted BENCH_* headline
# ratios to within 10% of the committed baselines/ floors.
run ./tools/bench_gate.sh --self-test
run ./tools/bench_gate.sh

# The unified executor / autoscaler surfaces are documented contracts;
# rotted intra-doc links on them (e.g. a renamed trait method) fail CI.
run env RUSTDOCFLAGS="-D rustdoc::broken-intra-doc-links" cargo doc --no-deps

# Keep the feature-gated PJRT backend compiling when its vendored xla
# dependency is enabled in Cargo.toml (it cannot resolve otherwise, so
# skip with a warning on the offline image).
if grep -Eq '^[[:space:]]*xla[[:space:]]*=' Cargo.toml; then
    run cargo check --features pjrt
else
    echo "==> xla dependency not enabled in Cargo.toml; skipping cargo check --features pjrt" >&2
fi

if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --check
else
    echo "==> cargo fmt not installed; skipping format check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy --all-targets -- -D warnings
else
    echo "==> cargo clippy not installed; skipping lints" >&2
fi

echo "==> ci.sh OK"
