#!/usr/bin/env sh
# CI gate: build, tests, formatting, lints.  Run from the repo root.
#
#   ./ci.sh          # everything
#   ./ci.sh fast     # build + tests only (skip fmt/clippy)
#
# The crate is dependency-free by design (offline build image), so a bare
# rust toolchain is all this needs.  fmt/clippy steps are skipped with a
# warning when the components are not installed.

set -eu

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH — install a Rust toolchain first" >&2
    exit 1
fi

run() {
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q

if [ "${1:-}" = "fast" ]; then
    echo "==> skipping fmt/clippy (fast mode)"
    exit 0
fi

if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --check
else
    echo "==> cargo fmt not installed; skipping format check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy --all-targets -- -D warnings
else
    echo "==> cargo clippy not installed; skipping lints" >&2
fi

echo "==> ci.sh OK"
